//! Matrix and results I/O: the paldx binary formats (dense + condensed),
//! CSV export, point-cloud (`.vec`) and edge-list loading, and a minimal
//! JSON writer for results (no serde in the offline cache).
//!
//! All distance-input loaders return typed [`PaldError`]s — callers can
//! distinguish a missing file ([`PaldError::Io`]) from corrupt contents
//! ([`PaldError::BadFormat`]) from a structurally impossible payload
//! (e.g. [`PaldError::NotTriangular`]).  Binary payloads are read with a
//! single `read_exact` into one buffer and decoded in bulk — not four
//! bytes at a time.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::core::Mat;
use crate::pald::{CondensedMatrix, PaldError};

/// Magic header of the dense binary matrix format.
pub const MAGIC_DENSE: &[u8; 8] = b"PALDMAT1";
/// Magic header of the condensed (upper-triangular) binary format.
pub const MAGIC_CONDENSED: &[u8; 8] = b"PALDCND1";

fn ioerr(path: &Path) -> impl Fn(std::io::Error) -> PaldError + '_ {
    move |e| PaldError::io(path, e)
}

/// Decode a little-endian `f32` payload in one pass.
fn decode_f32(buf: &[u8]) -> Vec<f32> {
    buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

/// Encode an `f32` slice to little-endian bytes in one pass.
fn encode_f32(vals: &[f32]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    buf
}

/// Read exactly `count` little-endian `f32`s through one `read_exact`.
fn read_f32_bulk<R: Read>(r: &mut R, count: usize, path: &Path) -> Result<Vec<f32>, PaldError> {
    let mut buf = vec![0u8; count * 4];
    r.read_exact(&mut buf).map_err(ioerr(path))?;
    Ok(decode_f32(&buf))
}

fn read_u64<R: Read>(r: &mut R, path: &Path) -> Result<u64, PaldError> {
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8).map_err(ioerr(path))?;
    Ok(u64::from_le_bytes(b8))
}

/// Write a matrix in the paldx dense binary format (magic, dims, f32 LE
/// data).
pub fn save_matrix(m: &Mat, path: &Path) -> Result<(), PaldError> {
    let mut w = BufWriter::new(File::create(path).map_err(ioerr(path))?);
    w.write_all(MAGIC_DENSE).map_err(ioerr(path))?;
    w.write_all(&(m.rows() as u64).to_le_bytes()).map_err(ioerr(path))?;
    w.write_all(&(m.cols() as u64).to_le_bytes()).map_err(ioerr(path))?;
    w.write_all(&encode_f32(m.as_slice())).map_err(ioerr(path))?;
    Ok(())
}

/// Read a matrix written by [`save_matrix`].  The payload is read with a
/// single `read_exact` into one byte buffer and decoded in bulk.
pub fn load_matrix(path: &Path) -> Result<Mat, PaldError> {
    let mut r = BufReader::new(File::open(path).map_err(ioerr(path))?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).map_err(ioerr(path))?;
    if &magic != MAGIC_DENSE {
        return Err(PaldError::bad_format(path, "bad magic (not a paldx dense matrix)"));
    }
    let rows = read_u64(&mut r, path)? as usize;
    let cols = read_u64(&mut r, path)? as usize;
    if rows.checked_mul(cols).map(|n| n >= (1 << 32)).unwrap_or(true) {
        return Err(PaldError::bad_format(path, format!("unreasonable matrix size {rows}x{cols}")));
    }
    let data = read_f32_bulk(&mut r, rows * cols, path)?;
    Ok(Mat::from_vec(rows, cols, data))
}

/// Write a condensed distance matrix (magic, n, the `n(n-1)/2` upper-
/// triangular f32 LE values) — half the bytes of the dense format.
pub fn save_condensed(c: &CondensedMatrix, path: &Path) -> Result<(), PaldError> {
    use crate::pald::DistanceInput;
    let mut w = BufWriter::new(File::create(path).map_err(ioerr(path))?);
    w.write_all(MAGIC_CONDENSED).map_err(ioerr(path))?;
    w.write_all(&(c.n() as u64).to_le_bytes()).map_err(ioerr(path))?;
    w.write_all(&encode_f32(c.as_slice())).map_err(ioerr(path))?;
    Ok(())
}

/// Read a condensed distance matrix written by [`save_condensed`].
pub fn load_condensed(path: &Path) -> Result<CondensedMatrix, PaldError> {
    let mut r = BufReader::new(File::open(path).map_err(ioerr(path))?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).map_err(ioerr(path))?;
    if &magic != MAGIC_CONDENSED {
        return Err(PaldError::bad_format(path, "bad magic (not a paldx condensed matrix)"));
    }
    let n = read_u64(&mut r, path)? as usize;
    if n < 2 || n >= (1 << 17) {
        return Err(PaldError::bad_format(path, format!("unreasonable point count {n}")));
    }
    let data = read_f32_bulk(&mut r, n * (n - 1) / 2, path)?;
    CondensedMatrix::new(n, data)
}

/// Peek the 8-byte magic of a paldx binary file (dispatching `--input`
/// between the dense and condensed loaders).
pub fn peek_magic(path: &Path) -> Result<[u8; 8], PaldError> {
    let mut r = File::open(path).map_err(ioerr(path))?;
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).map_err(ioerr(path))?;
    Ok(magic)
}

/// CSV export (header-less, one row per line).
pub fn save_csv(m: &Mat, path: &Path) -> Result<(), PaldError> {
    let mut w = BufWriter::new(File::create(path).map_err(ioerr(path))?);
    for i in 0..m.rows() {
        let row: Vec<String> = m.row(i).iter().map(|v| format!("{v}")).collect();
        writeln!(w, "{}", row.join(",")).map_err(ioerr(path))?;
    }
    Ok(())
}

/// Load a matrix from header-less CSV.
pub fn load_csv(path: &Path) -> Result<Mat, PaldError> {
    let r = BufReader::new(File::open(path).map_err(ioerr(path))?);
    let mut data = Vec::new();
    let mut cols = 0usize;
    let mut rows = 0usize;
    for line in r.lines() {
        let line = line.map_err(ioerr(path))?;
        if line.trim().is_empty() {
            continue;
        }
        let vals: Vec<f32> = line
            .split(',')
            .map(|s| s.trim().parse::<f32>())
            .collect::<Result<_, _>>()
            .map_err(|e| PaldError::bad_format(path, format!("row {rows}: {e}")))?;
        if cols == 0 {
            cols = vals.len();
        }
        if vals.len() != cols {
            return Err(PaldError::bad_format(path, format!("ragged CSV at row {rows}")));
        }
        data.extend(vals);
        rows += 1;
    }
    Ok(Mat::from_vec(rows, cols, data))
}

/// Load a point cloud from a `.vec` text file: one point per line,
/// whitespace-separated coordinates, with an optional leading word label
/// per line (the fastText convention) that is skipped when it does not
/// parse as a number.
///
/// Caveat of the label heuristic: a file whose labels *all* happen to
/// parse as numbers (`"1984 0.1 0.2"`) is indistinguishable from an
/// unlabeled file with one more dimension and is ingested as such;
/// `nan`/`inf` labels likewise become coordinates, where the facade's
/// default strict validation rejects them at compute time.
pub fn load_points(path: &Path) -> Result<Mat, PaldError> {
    let r = BufReader::new(File::open(path).map_err(ioerr(path))?);
    let mut data = Vec::new();
    let mut dim = 0usize;
    let mut rows = 0usize;
    for line in r.lines() {
        let line = line.map_err(ioerr(path))?;
        let mut tokens = line.split_whitespace().peekable();
        // Optional word label: skip the first token iff it is not numeric.
        if let Some(first) = tokens.peek() {
            if first.parse::<f32>().is_err() {
                tokens.next();
            }
        }
        let vals: Vec<f32> = tokens
            .map(|s| s.parse::<f32>())
            .collect::<Result<_, _>>()
            .map_err(|e| PaldError::bad_format(path, format!("point {rows}: {e}")))?;
        if vals.is_empty() {
            continue;
        }
        if dim == 0 {
            dim = vals.len();
        }
        if vals.len() != dim {
            return Err(PaldError::bad_format(
                path,
                format!("point {rows} has {} coordinates, expected {dim}", vals.len()),
            ));
        }
        data.extend(vals);
        rows += 1;
    }
    if rows == 0 {
        return Err(PaldError::bad_format(path, "no points in file"));
    }
    Ok(Mat::from_vec(rows, dim, data))
}

/// Load an undirected edge list: whitespace-separated `u v` per line,
/// `#` comments allowed (the SNAP format).
pub fn load_edge_list(path: &Path) -> anyhow::Result<(usize, Vec<(u32, u32)>)> {
    let r = BufReader::new(File::open(path)?);
    let mut edges = Vec::new();
    let mut max_v = 0u32;
    for line in r.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let a: u32 = it.next().ok_or_else(|| anyhow::anyhow!("bad edge line"))?.parse()?;
        let b: u32 = it.next().ok_or_else(|| anyhow::anyhow!("bad edge line"))?.parse()?;
        max_v = max_v.max(a).max(b);
        edges.push((a, b));
    }
    Ok((max_v as usize + 1, edges))
}

/// Minimal JSON value writer for results/metrics files.
pub enum Json {
    /// A number (non-finite renders as `null`).
    Num(f64),
    /// A string (escaped on render).
    Str(String),
    /// A boolean.
    Bool(bool),
    /// An array.
    Arr(Vec<Json>),
    /// An object with insertion-ordered keys.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Serialize to JSON text.
    pub fn render(&self) -> String {
        match self {
            Json::Num(v) => {
                if v.is_finite() {
                    format!("{v}")
                } else {
                    "null".into()
                }
            }
            Json::Bool(b) => format!("{b}"),
            Json::Str(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
            Json::Arr(a) => {
                format!("[{}]", a.iter().map(Json::render).collect::<Vec<_>>().join(","))
            }
            Json::Obj(o) => format!(
                "{{{}}}",
                o.iter()
                    .map(|(k, v)| format!("\"{k}\":{}", v.render()))
                    .collect::<Vec<_>>()
                    .join(",")
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::distmat;
    use crate::pald::DistanceInput;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("paldx_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn binary_roundtrip() {
        let m = distmat::random_tie_free(17, 3);
        let p = tmp("m.bin");
        save_matrix(&m, &p).unwrap();
        let m2 = load_matrix(&p).unwrap();
        assert_eq!(m.as_slice(), m2.as_slice());
    }

    #[test]
    fn binary_roundtrip_1k() {
        // ~1k x 1k: exercises the bulk read_exact path on a 4 MB payload.
        let n = 1000;
        let m = Mat::from_fn(n, n, |i, j| (i * 31 + j * 7) as f32 * 0.25);
        let p = tmp("m1k.bin");
        save_matrix(&m, &p).unwrap();
        let m2 = load_matrix(&p).unwrap();
        assert_eq!(m.as_slice(), m2.as_slice());
        assert_eq!(m2.rows(), n);
    }

    #[test]
    fn condensed_roundtrip_and_magic_dispatch() {
        let d = distmat::random_tie_free(40, 8);
        let c = CondensedMatrix::from_dense(&d).unwrap();
        let p = tmp("m.cnd.bin");
        save_condensed(&c, &p).unwrap();
        assert_eq!(&peek_magic(&p).unwrap(), MAGIC_CONDENSED);
        let c2 = load_condensed(&p).unwrap();
        assert_eq!(c.as_slice(), c2.as_slice());
        assert_eq!(c2.to_dense().as_slice(), d.as_slice());
        // A condensed file is slightly under half the dense file's bytes.
        let pd = tmp("m.dense.bin");
        save_matrix(&d, &pd).unwrap();
        let cnd_len = std::fs::metadata(&p).unwrap().len();
        let dns_len = std::fs::metadata(&pd).unwrap().len();
        assert!(cnd_len < dns_len / 2 + 64, "condensed {cnd_len} vs dense {dns_len}");
    }

    #[test]
    fn wrong_magic_is_a_typed_error() {
        let d = distmat::random_tie_free(6, 1);
        let p = tmp("dense_as_condensed.bin");
        save_matrix(&d, &p).unwrap();
        assert!(matches!(load_condensed(&p), Err(PaldError::BadFormat { .. })));
        let missing = tmp("does_not_exist.bin");
        assert!(matches!(load_matrix(&missing), Err(PaldError::Io { .. })));
    }

    #[test]
    fn csv_roundtrip() {
        let m = distmat::random_uniform(9, 5);
        let p = tmp("m.csv");
        save_csv(&m, &p).unwrap();
        let m2 = load_csv(&p).unwrap();
        assert!(m.allclose(&m2, 1e-5, 1e-6));
    }

    #[test]
    fn points_file_with_and_without_labels() {
        let p = tmp("pts.vec");
        std::fs::write(&p, "word1 0.5 1.0 -2.0\nword2 1.5 2.0 3.5\n0.0 0.0 1.0\n").unwrap();
        let pts = load_points(&p).unwrap();
        assert_eq!((pts.rows(), pts.cols()), (3, 3));
        assert_eq!(pts[(0, 2)], -2.0);
        assert_eq!(pts[(2, 2)], 1.0);

        let ragged = tmp("ragged.vec");
        std::fs::write(&ragged, "a 1.0 2.0\nb 1.0\n").unwrap();
        assert!(matches!(load_points(&ragged), Err(PaldError::BadFormat { .. })));
    }

    #[test]
    fn edge_list_parsing() {
        let p = tmp("g.txt");
        std::fs::write(&p, "# comment\n0 1\n1 2\n\n2 3\n").unwrap();
        let (n, edges) = load_edge_list(&p).unwrap();
        assert_eq!(n, 4);
        assert_eq!(edges, vec![(0, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("junk.bin");
        std::fs::write(&p, b"NOTMAGIC________").unwrap();
        assert!(load_matrix(&p).is_err());
    }

    #[test]
    fn json_rendering() {
        let j = Json::Obj(vec![
            ("n".into(), Json::Num(2048.0)),
            ("alg".into(), Json::Str("opt-triplet".into())),
            ("ok".into(), Json::Bool(true)),
            ("xs".into(), Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)])),
        ]);
        assert_eq!(
            j.render(),
            r#"{"n":2048,"alg":"opt-triplet","ok":true,"xs":[1,2.5]}"#
        );
    }
}
