//! Matrix and results I/O: a simple binary matrix format, CSV export,
//! edge-list loading, and a minimal JSON writer for results (no serde in
//! the offline cache).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::core::Mat;

const MAGIC: &[u8; 8] = b"PALDMAT1";

/// Write a matrix in the paldx binary format (magic, dims, f32 LE data).
pub fn save_matrix(m: &Mat, path: &Path) -> anyhow::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(m.rows() as u64).to_le_bytes())?;
    w.write_all(&(m.cols() as u64).to_le_bytes())?;
    for &v in m.as_slice() {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read a matrix written by [`save_matrix`].
pub fn load_matrix(path: &Path) -> anyhow::Result<Mat> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "bad magic in {}", path.display());
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let rows = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let cols = u64::from_le_bytes(b8) as usize;
    anyhow::ensure!(rows * cols < (1 << 32), "unreasonable matrix size");
    let mut data = vec![0.0f32; rows * cols];
    let mut b4 = [0u8; 4];
    for v in &mut data {
        r.read_exact(&mut b4)?;
        *v = f32::from_le_bytes(b4);
    }
    Ok(Mat::from_vec(rows, cols, data))
}

/// CSV export (header-less, one row per line).
pub fn save_csv(m: &Mat, path: &Path) -> anyhow::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for i in 0..m.rows() {
        let row: Vec<String> = m.row(i).iter().map(|v| format!("{v}")).collect();
        writeln!(w, "{}", row.join(","))?;
    }
    Ok(())
}

/// Load a square matrix from header-less CSV.
pub fn load_csv(path: &Path) -> anyhow::Result<Mat> {
    let r = BufReader::new(File::open(path)?);
    let mut data = Vec::new();
    let mut cols = 0usize;
    let mut rows = 0usize;
    for line in r.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let vals: Vec<f32> = line
            .split(',')
            .map(|s| s.trim().parse::<f32>())
            .collect::<Result<_, _>>()?;
        if cols == 0 {
            cols = vals.len();
        }
        anyhow::ensure!(vals.len() == cols, "ragged CSV at row {rows}");
        data.extend(vals);
        rows += 1;
    }
    Ok(Mat::from_vec(rows, cols, data))
}

/// Load an undirected edge list: whitespace-separated `u v` per line,
/// `#` comments allowed (the SNAP format).
pub fn load_edge_list(path: &Path) -> anyhow::Result<(usize, Vec<(u32, u32)>)> {
    let r = BufReader::new(File::open(path)?);
    let mut edges = Vec::new();
    let mut max_v = 0u32;
    for line in r.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let a: u32 = it.next().ok_or_else(|| anyhow::anyhow!("bad edge line"))?.parse()?;
        let b: u32 = it.next().ok_or_else(|| anyhow::anyhow!("bad edge line"))?.parse()?;
        max_v = max_v.max(a).max(b);
        edges.push((a, b));
    }
    Ok((max_v as usize + 1, edges))
}

/// Minimal JSON value writer for results/metrics files.
pub enum Json {
    Num(f64),
    Str(String),
    Bool(bool),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn render(&self) -> String {
        match self {
            Json::Num(v) => {
                if v.is_finite() {
                    format!("{v}")
                } else {
                    "null".into()
                }
            }
            Json::Bool(b) => format!("{b}"),
            Json::Str(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
            Json::Arr(a) => {
                format!("[{}]", a.iter().map(Json::render).collect::<Vec<_>>().join(","))
            }
            Json::Obj(o) => format!(
                "{{{}}}",
                o.iter()
                    .map(|(k, v)| format!("\"{k}\":{}", v.render()))
                    .collect::<Vec<_>>()
                    .join(",")
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::distmat;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("paldx_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn binary_roundtrip() {
        let m = distmat::random_tie_free(17, 3);
        let p = tmp("m.bin");
        save_matrix(&m, &p).unwrap();
        let m2 = load_matrix(&p).unwrap();
        assert_eq!(m.as_slice(), m2.as_slice());
    }

    #[test]
    fn csv_roundtrip() {
        let m = distmat::random_uniform(9, 5);
        let p = tmp("m.csv");
        save_csv(&m, &p).unwrap();
        let m2 = load_csv(&p).unwrap();
        assert!(m.allclose(&m2, 1e-5, 1e-6));
    }

    #[test]
    fn edge_list_parsing() {
        let p = tmp("g.txt");
        std::fs::write(&p, "# comment\n0 1\n1 2\n\n2 3\n").unwrap();
        let (n, edges) = load_edge_list(&p).unwrap();
        assert_eq!(n, 4);
        assert_eq!(edges, vec![(0, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("junk.bin");
        std::fs::write(&p, b"NOTMAGIC________").unwrap();
        assert!(load_matrix(&p).is_err());
    }

    #[test]
    fn json_rendering() {
        let j = Json::Obj(vec![
            ("n".into(), Json::Num(2048.0)),
            ("alg".into(), Json::Str("opt-triplet".into())),
            ("ok".into(), Json::Bool(true)),
            ("xs".into(), Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)])),
        ]);
        assert_eq!(
            j.render(),
            r#"{"n":2048,"alg":"opt-triplet","ok":true,"xs":[1,2.5]}"#
        );
    }
}
