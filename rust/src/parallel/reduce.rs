//! Parallel-for with sum reduction (`#pragma omp parallel for reduction(+:...)`).
//!
//! Each thread accumulates into a private buffer; buffers are combined
//! after the join.  This is exactly the synchronization the paper charges
//! the parallel pairwise focus pass for ("all threads must write to
//! U[X,Y], so a sum-reduction is required") and the reason that pass stops
//! scaling in Figure 13.

use crate::parallel::pool::{parallel_for_ranges, Schedule};
use std::sync::Mutex;

/// Run `body(range, &mut acc)` over a partition of `0..len`; each thread
/// gets its own `f32` accumulator buffer of length `acc_len`, and the
/// per-thread buffers are summed into the returned vector.
pub fn parallel_for_reduce<F>(
    len: usize,
    acc_len: usize,
    threads: usize,
    schedule: Schedule,
    body: F,
) -> Vec<f32>
where
    F: Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 {
        let mut acc = vec![0.0f32; acc_len];
        body(0..len, &mut acc);
        return acc;
    }
    let result = Mutex::new(vec![0.0f32; acc_len]);
    parallel_for_ranges(len, threads, schedule, |_, range| {
        let mut local = vec![0.0f32; acc_len];
        body(range, &mut local);
        let mut guard = result.lock().unwrap();
        for (g, l) in guard.iter_mut().zip(&local) {
            *g += l;
        }
    });
    result.into_inner().unwrap()
}

/// Integer-accumulator variant (the optimized algorithms keep U integral).
pub fn parallel_for_reduce_u32<F>(
    len: usize,
    acc_len: usize,
    threads: usize,
    schedule: Schedule,
    body: F,
) -> Vec<u32>
where
    F: Fn(std::ops::Range<usize>, &mut [u32]) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 {
        let mut acc = vec![0u32; acc_len];
        body(0..len, &mut acc);
        return acc;
    }
    let result = Mutex::new(vec![0u32; acc_len]);
    parallel_for_ranges(len, threads, schedule, |_, range| {
        let mut local = vec![0u32; acc_len];
        body(range, &mut local);
        let mut guard = result.lock().unwrap();
        for (g, l) in guard.iter_mut().zip(&local) {
            *g += l;
        }
    });
    result.into_inner().unwrap()
}

/// Reusable per-thread accumulator buffers for repeated reductions.
///
/// [`parallel_for_reduce_u32`] allocates one private buffer per thread per
/// call; in the serving path (a [`crate::pald::Session`] computing many
/// matrices back to back) those allocations dominate the focus-pass
/// overhead.  A `ReduceWorkspace` keeps the buffers alive across calls —
/// steady state is allocation-free.
#[derive(Default)]
pub struct ReduceWorkspace {
    bufs: Vec<Vec<u32>>,
    bufs_f64: Vec<Vec<f64>>,
}

impl ReduceWorkspace {
    /// Bytes currently held by the per-thread buffers.
    pub fn allocated_bytes(&self) -> usize {
        self.bufs.iter().map(|b| b.capacity() * std::mem::size_of::<u32>()).sum::<usize>()
            + self
                .bufs_f64
                .iter()
                .map(|b| b.capacity() * std::mem::size_of::<f64>())
                .sum::<usize>()
    }

    /// Size (and zero) `threads` buffers of `acc_len` words, reusing
    /// existing capacity.
    fn ensure(&mut self, threads: usize, acc_len: usize) {
        if self.bufs.len() < threads {
            self.bufs.resize_with(threads, Vec::new);
        }
        for b in self.bufs.iter_mut().take(threads) {
            b.clear();
            b.resize(acc_len, 0);
        }
    }

    /// Size (and zero) `threads` f64 buffers of `acc_len` words.
    fn ensure_f64(&mut self, threads: usize, acc_len: usize) {
        if self.bufs_f64.len() < threads {
            self.bufs_f64.resize_with(threads, Vec::new);
        }
        for b in self.bufs_f64.iter_mut().take(threads) {
            b.clear();
            b.resize(acc_len, 0.0);
        }
    }
}

/// Like [`parallel_for_reduce_u32`], but accumulating into the caller's
/// `out` (which must be zeroed) and reusing `ws`'s per-thread buffers
/// across calls.  Static schedule (the pairwise focus pass is uniform).
pub fn parallel_for_reduce_u32_into<F>(
    len: usize,
    threads: usize,
    ws: &mut ReduceWorkspace,
    out: &mut [u32],
    body: F,
) where
    F: Fn(std::ops::Range<usize>, &mut [u32]) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 {
        body(0..len, out);
        return;
    }
    ws.ensure(threads, out.len());
    let chunk = len.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, buf) in ws.bufs.iter_mut().enumerate().take(threads) {
            let lo = (t * chunk).min(len);
            let hi = ((t + 1) * chunk).min(len);
            let body = &body;
            s.spawn(move || body(lo..hi, &mut buf[..]));
        }
    });
    for buf in ws.bufs.iter().take(threads) {
        for (o, v) in out.iter_mut().zip(buf) {
            *o += *v;
        }
    }
}

/// Float sum-reduction over an index range (e.g. a CSR edge range) into
/// the caller's zeroed `out`, with reused per-thread f64 buffers and a
/// **fixed merge order** (per-thread partials combined in ascending
/// thread id after the join).
///
/// Determinism contract: repeated runs at the *same* thread count are
/// bit-identical (static schedule + fixed merge order), but runs at
/// *different* thread counts are only tolerance-level reproducible —
/// float partial sums round differently than one running sum.  This is
/// why the sparse parallel kernels do **not** merge per-thread support
/// buffers: their bit-identity anchor against the sequential kernels
/// requires conflict-free column ownership instead (DESIGN.md §10).
/// Use this reduction where a cross-thread sum is the right tool and
/// run-to-run reproducibility at a fixed budget is enough.  The f64
/// accumulator keeps the partials exact far beyond f32 edge weights.
pub fn parallel_for_reduce_f64_into<F>(
    len: usize,
    threads: usize,
    ws: &mut ReduceWorkspace,
    out: &mut [f64],
    body: F,
) where
    F: Fn(std::ops::Range<usize>, &mut [f64]) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 {
        body(0..len, out);
        return;
    }
    ws.ensure_f64(threads, out.len());
    let chunk = len.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, buf) in ws.bufs_f64.iter_mut().enumerate().take(threads) {
            let lo = (t * chunk).min(len);
            let hi = ((t + 1) * chunk).min(len);
            let body = &body;
            s.spawn(move || body(lo..hi, &mut buf[..]));
        }
    });
    for buf in ws.bufs_f64.iter().take(threads) {
        for (o, v) in out.iter_mut().zip(buf) {
            *o += *v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_into_matches_allocating_variant() {
        let body = |range: std::ops::Range<usize>, acc: &mut [u32]| {
            for i in range {
                acc[i % 8] += (i as u32) % 5;
            }
        };
        let want = parallel_for_reduce_u32(1000, 8, 4, Schedule::Static, body);
        let mut ws = ReduceWorkspace::default();
        let mut out = vec![0u32; 8];
        parallel_for_reduce_u32_into(1000, 4, &mut ws, &mut out, body);
        assert_eq!(out, want);
        // Second call reuses buffers and still sums correctly.
        out.fill(0);
        parallel_for_reduce_u32_into(1000, 4, &mut ws, &mut out, body);
        assert_eq!(out, want);
    }

    #[test]
    fn reduce_into_single_thread() {
        let mut ws = ReduceWorkspace::default();
        let mut out = vec![0u32; 2];
        parallel_for_reduce_u32_into(10, 1, &mut ws, &mut out, |range, acc| {
            acc[0] += range.len() as u32;
        });
        assert_eq!(out[0], 10);
    }

    #[test]
    fn reduce_sums_partials() {
        // acc[j] += i for every i in 0..100, j = i % 4
        let acc = parallel_for_reduce(100, 4, 4, Schedule::Static, |range, acc| {
            for i in range {
                acc[i % 4] += i as f32;
            }
        });
        let want: Vec<f32> = (0..4)
            .map(|j| (0..100).filter(|i| i % 4 == j).sum::<usize>() as f32)
            .collect();
        assert_eq!(acc, want);
    }

    #[test]
    fn reduce_u32_matches_sequential() {
        let par = parallel_for_reduce_u32(1000, 8, 8, Schedule::Dynamic(7), |range, acc| {
            for i in range {
                acc[i % 8] += 1;
            }
        });
        let mut seq = vec![0u32; 8];
        for i in 0..1000 {
            seq[i % 8] += 1;
        }
        assert_eq!(par, seq);
    }

    #[test]
    fn reduce_f64_is_repeatable_at_fixed_thread_count() {
        let body = |range: std::ops::Range<usize>, acc: &mut [f64]| {
            for i in range {
                acc[i % 16] += 1.0 / (i + 1) as f64;
            }
        };
        let mut ws = ReduceWorkspace::default();
        let mut a = vec![0.0f64; 16];
        parallel_for_reduce_f64_into(5000, 4, &mut ws, &mut a, body);
        let bytes = ws.allocated_bytes();
        let mut b = vec![0.0f64; 16];
        parallel_for_reduce_f64_into(5000, 4, &mut ws, &mut b, body);
        assert_eq!(a, b, "fixed thread count must be bitwise repeatable");
        assert_eq!(ws.allocated_bytes(), bytes, "steady state must not grow");
        // ... and single-thread agrees within tolerance (not bitwise:
        // partial sums round differently than one running sum).
        let mut seq = vec![0.0f64; 16];
        parallel_for_reduce_f64_into(5000, 1, &mut ws, &mut seq, body);
        for (x, y) in a.iter().zip(&seq) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }
    }

    #[test]
    fn single_thread_shortcut() {
        let acc = parallel_for_reduce(10, 1, 1, Schedule::Static, |range, acc| {
            acc[0] += range.len() as f32;
        });
        assert_eq!(acc[0], 10.0);
    }
}
