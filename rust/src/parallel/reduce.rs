//! Parallel-for with sum reduction (`#pragma omp parallel for reduction(+:...)`).
//!
//! Each thread accumulates into a private buffer; buffers are combined
//! after the join.  This is exactly the synchronization the paper charges
//! the parallel pairwise focus pass for ("all threads must write to
//! U[X,Y], so a sum-reduction is required") and the reason that pass stops
//! scaling in Figure 13.

use crate::parallel::pool::{parallel_for_ranges, Schedule};
use std::sync::Mutex;

/// Run `body(range, &mut acc)` over a partition of `0..len`; each thread
/// gets its own `f32` accumulator buffer of length `acc_len`, and the
/// per-thread buffers are summed into the returned vector.
pub fn parallel_for_reduce<F>(
    len: usize,
    acc_len: usize,
    threads: usize,
    schedule: Schedule,
    body: F,
) -> Vec<f32>
where
    F: Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 {
        let mut acc = vec![0.0f32; acc_len];
        body(0..len, &mut acc);
        return acc;
    }
    let result = Mutex::new(vec![0.0f32; acc_len]);
    parallel_for_ranges(len, threads, schedule, |_, range| {
        let mut local = vec![0.0f32; acc_len];
        body(range, &mut local);
        let mut guard = result.lock().unwrap();
        for (g, l) in guard.iter_mut().zip(&local) {
            *g += l;
        }
    });
    result.into_inner().unwrap()
}

/// Integer-accumulator variant (the optimized algorithms keep U integral).
pub fn parallel_for_reduce_u32<F>(
    len: usize,
    acc_len: usize,
    threads: usize,
    schedule: Schedule,
    body: F,
) -> Vec<u32>
where
    F: Fn(std::ops::Range<usize>, &mut [u32]) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 {
        let mut acc = vec![0u32; acc_len];
        body(0..len, &mut acc);
        return acc;
    }
    let result = Mutex::new(vec![0u32; acc_len]);
    parallel_for_ranges(len, threads, schedule, |_, range| {
        let mut local = vec![0u32; acc_len];
        body(range, &mut local);
        let mut guard = result.lock().unwrap();
        for (g, l) in guard.iter_mut().zip(&local) {
            *g += l;
        }
    });
    result.into_inner().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_sums_partials() {
        // acc[j] += i for every i in 0..100, j = i % 4
        let acc = parallel_for_reduce(100, 4, 4, Schedule::Static, |range, acc| {
            for i in range {
                acc[i % 4] += i as f32;
            }
        });
        let want: Vec<f32> = (0..4)
            .map(|j| (0..100).filter(|i| i % 4 == j).sum::<usize>() as f32)
            .collect();
        assert_eq!(acc, want);
    }

    #[test]
    fn reduce_u32_matches_sequential() {
        let par = parallel_for_reduce_u32(1000, 8, 8, Schedule::Dynamic(7), |range, acc| {
            for i in range {
                acc[i % 8] += 1;
            }
        });
        let mut seq = vec![0u32; 8];
        for i in 0..1000 {
            seq[i % 8] += 1;
        }
        assert_eq!(par, seq);
    }

    #[test]
    fn single_thread_shortcut() {
        let acc = parallel_for_reduce(10, 1, 1, Schedule::Static, |range, acc| {
            acc[0] += range.len() as f32;
        });
        assert_eq!(acc[0], 10.0);
    }
}
