//! Scoped fork-join loop parallelism (`#pragma omp parallel for`).
//!
//! `std::thread::scope` gives us structured parallelism without 'static
//! bounds; a static schedule hands thread `t` the `t`-th contiguous chunk
//! (the paper's best schedule for pairwise, whose iterations are uniform),
//! and the dynamic schedule hands out fixed-size chunks from an atomic
//! counter (for irregular work).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Loop schedule, mirroring OpenMP's `schedule(static)` / `schedule(dynamic, k)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// Contiguous per-thread chunks, decided up front.
    Static,
    /// Work-stealing from a shared counter in chunks of the given size.
    Dynamic(usize),
}

/// Run `body(thread_id, range)` over a partition of `0..len` on `threads`
/// threads.  `body` must be safe to run concurrently on disjoint ranges.
pub fn parallel_for_ranges<F>(len: usize, threads: usize, schedule: Schedule, body: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || len <= 1 {
        body(0, 0..len);
        return;
    }
    match schedule {
        Schedule::Static => {
            let chunk = len.div_ceil(threads);
            std::thread::scope(|s| {
                for t in 0..threads {
                    let lo = (t * chunk).min(len);
                    let hi = ((t + 1) * chunk).min(len);
                    let body = &body;
                    s.spawn(move || body(t, lo..hi));
                }
            });
        }
        Schedule::Dynamic(chunk) => {
            let chunk = chunk.max(1);
            let next = AtomicUsize::new(0);
            std::thread::scope(|s| {
                for t in 0..threads {
                    let next = &next;
                    let body = &body;
                    s.spawn(move || loop {
                        let lo = next.fetch_add(chunk, Ordering::Relaxed);
                        if lo >= len {
                            break;
                        }
                        body(t, lo..(lo + chunk).min(len));
                    });
                }
            });
        }
    }
}

/// Run `body(i)` for every `i in 0..len` in parallel.
pub fn parallel_for<F>(len: usize, threads: usize, schedule: Schedule, body: F)
where
    F: Fn(usize) + Sync,
{
    parallel_for_ranges(len, threads, schedule, |_, range| {
        for i in range {
            body(i);
        }
    });
}

/// Marker wrapper that promises the wrapped pointer is used for disjoint
/// writes only (each index written by at most one thread), making it Sync.
///
/// The pairwise cohesion pass writes column-disjoint slices of C from
/// different threads; Rust cannot prove that, so the kernels use this
/// wrapper with an explicit safety argument at each use site.
pub struct DisjointWriter<T>(pub *mut T);

unsafe impl<T: Send> Sync for DisjointWriter<T> {}
unsafe impl<T: Send> Send for DisjointWriter<T> {}

impl<T> DisjointWriter<T> {
    /// # Safety
    /// Caller must guarantee `idx` is written by exactly one thread during
    /// the parallel region and read by none.
    #[inline(always)]
    pub unsafe fn add_at(&self, idx: usize, v: T)
    where
        T: std::ops::AddAssign,
    {
        *self.0.add(idx) += v;
    }

    /// # Safety
    /// As [`DisjointWriter::add_at`].
    #[inline(always)]
    pub unsafe fn write_at(&self, idx: usize, v: T) {
        *self.0.add(idx) = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn static_schedule_covers_all_indices_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1000, 4, Schedule::Static, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn dynamic_schedule_covers_all_indices_once() {
        let hits: Vec<AtomicU64> = (0..777).map(|_| AtomicU64::new(0)).collect();
        parallel_for(777, 8, Schedule::Dynamic(13), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_fallback() {
        let sum = AtomicU64::new(0);
        parallel_for(100, 1, Schedule::Static, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn ranges_partition_is_disjoint_and_complete() {
        for threads in [2usize, 3, 7] {
            for len in [0usize, 1, 10, 97] {
                let seen: Vec<AtomicU64> = (0..len).map(|_| AtomicU64::new(0)).collect();
                parallel_for_ranges(len, threads, Schedule::Static, |_, r| {
                    for i in r {
                        seen[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert!(seen.iter().all(|h| h.load(Ordering::Relaxed) == 1));
            }
        }
    }

    #[test]
    fn disjoint_writer_sums() {
        let mut data = vec![0.0f64; 64];
        let w = DisjointWriter(data.as_mut_ptr());
        parallel_for(64, 4, Schedule::Static, |i| unsafe {
            w.add_at(i, i as f64);
        });
        assert_eq!(data[63], 63.0);
        assert_eq!(data.iter().sum::<f64>(), 2016.0);
    }
}
