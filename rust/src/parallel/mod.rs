//! Shared-memory parallel runtime, built from scratch on `std::thread`
//! (the offline cache has no rayon/crossbeam), mirroring the OpenMP
//! constructs the paper uses:
//!
//! * [`parallel_for`] / [`Pool`] — `#pragma omp parallel for` with static
//!   or dynamic schedules;
//! * [`reduce::parallel_for_reduce`] — `reduction(+: U[X,Y])`;
//! * [`taskgraph`] — `#pragma omp task untied depend(inout, ...)`: tasks
//!   declare the tiles they write, and the executor serializes conflicting
//!   tasks exactly like the OpenMP dependence graph in Figure 8.

pub mod pool;
pub mod reduce;
pub mod taskgraph;

pub use pool::{parallel_for, Schedule};
