//! Task-graph executor with `depend(inout)` semantics (`#pragma omp task
//! untied depend(inout, ...)`), used by the parallel triplet algorithm.
//!
//! Each task declares the resource ids (matrix tiles) it reads+writes.
//! Two tasks conflict iff their resource sets intersect — the edges of the
//! paper's Figure 8.  The executor runs a worker pool over a shared queue;
//! a worker claims a task by acquiring *all* its resource locks in
//! canonical (sorted) order, which is deadlock-free, and otherwise
//! requeues it.  This serializes conflicting tasks while letting
//! independent tasks run anywhere — the "untied" behaviour the paper found
//! fastest.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// A unit of work plus the resources it writes (inout dependencies).
pub struct Task<'a> {
    /// Sorted, deduplicated resource ids this task mutates.
    pub resources: Vec<usize>,
    /// The work; receives the worker thread id.
    pub run: Box<dyn Fn(usize) + Send + Sync + 'a>,
}

impl<'a> Task<'a> {
    /// A task over a (deduplicated, sorted) resource set.
    pub fn new(mut resources: Vec<usize>, run: impl Fn(usize) + Send + Sync + 'a) -> Self {
        resources.sort_unstable();
        resources.dedup();
        Task { resources, run: Box::new(run) }
    }
}

/// Execute `tasks` on `threads` workers; `num_resources` is the size of
/// the lock table.  Conflicting tasks never run concurrently.
pub fn execute<'a>(tasks: Vec<Task<'a>>, num_resources: usize, threads: usize) {
    let threads = threads.max(1);
    if threads == 1 {
        for t in &tasks {
            (t.run)(0);
        }
        return;
    }
    let locks: Vec<AtomicBool> = (0..num_resources).map(|_| AtomicBool::new(false)).collect();
    let queue: Mutex<VecDeque<Task<'a>>> = Mutex::new(tasks.into());

    // Try to acquire every resource; on failure release what we took.
    let try_acquire = |res: &[usize]| -> bool {
        for (k, &r) in res.iter().enumerate() {
            if locks[r].swap(true, Ordering::Acquire) {
                for &q in &res[..k] {
                    locks[q].store(false, Ordering::Release);
                }
                return false;
            }
        }
        true
    };
    let release = |res: &[usize]| {
        for &r in res {
            locks[r].store(false, Ordering::Release);
        }
    };

    std::thread::scope(|s| {
        for tid in 0..threads {
            let queue = &queue;
            let try_acquire = &try_acquire;
            let release = &release;
            s.spawn(move || loop {
                let task = {
                    let mut q = queue.lock().unwrap();
                    match q.pop_front() {
                        Some(t) => t,
                        None => break,
                    }
                };
                if try_acquire(&task.resources) {
                    (task.run)(tid);
                    release(&task.resources);
                } else {
                    // Conflict: requeue at the back and yield so the
                    // holder can finish (OpenMP would suspend the task).
                    queue.lock().unwrap().push_back(task);
                    std::thread::yield_now();
                }
            });
        }
    });
}

/// Resource id for tile (i, j) of an `nb x nb` tile grid — the canonical
/// key both U and C tiles use (layered: matrix id * nb^2 + i * nb + j).
pub fn tile_id(matrix: usize, nb: usize, i: usize, j: usize) -> usize {
    matrix * nb * nb + i * nb + j
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn all_tasks_run_once() {
        let counter = AtomicU64::new(0);
        let tasks: Vec<Task> = (0..100)
            .map(|i| {
                Task::new(vec![i % 7], |_| {
                    counter.fetch_add(1, Ordering::Relaxed);
                })
            })
            .collect();
        execute(tasks, 7, 4);
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn conflicting_tasks_are_serialized() {
        // All tasks share resource 0 and increment a non-atomic counter;
        // any overlap would lose updates (and be UB caught by Miri, but
        // here we just check the final count).
        let mut value = 0u64;
        let ptr = crate::parallel::pool::DisjointWriter(&mut value as *mut u64);
        let tasks: Vec<Task> = (0..200)
            .map(|_| {
                let ptr = &ptr;
                Task::new(vec![0], move |_| unsafe {
                    let v = *ptr.0;
                    // Lengthen the critical section to catch races.
                    std::hint::black_box(v);
                    ptr.write_at(0, v + 1);
                })
            })
            .collect();
        execute(tasks, 1, 8);
        assert_eq!(value, 200);
    }

    #[test]
    fn disjoint_tasks_all_execute_with_many_threads() {
        let hits: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(0)).collect();
        let tasks: Vec<Task> = (0..64)
            .map(|i| {
                let hits = &hits;
                Task::new(vec![i], move |_| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                })
            })
            .collect();
        execute(tasks, 64, 8);
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn multi_resource_tasks_respect_all_locks() {
        // Tasks touch overlapping pairs of resources; final per-resource
        // counts must equal the number of tasks that declared them.
        let mut counts = vec![0u64; 10];
        let base = counts.as_mut_ptr();
        let w = crate::parallel::pool::DisjointWriter(base);
        let tasks: Vec<Task> = (0..300)
            .map(|i| {
                let w = &w;
                let (a, b) = (i % 10, (i * 3 + 1) % 10);
                Task::new(vec![a, b], move |_| unsafe {
                    let pa = *w.0.add(a);
                    std::hint::black_box(pa);
                    w.write_at(a, pa + 1);
                    if b != a {
                        let pb = *w.0.add(b);
                        std::hint::black_box(pb);
                        w.write_at(b, pb + 1);
                    }
                })
            })
            .collect();
        execute(tasks, 10, 6);
        let mut want = vec![0u64; 10];
        for i in 0..300usize {
            let (a, b) = (i % 10, (i * 3 + 1) % 10);
            want[a] += 1;
            if b != a {
                want[b] += 1;
            }
        }
        assert_eq!(counts, want);
    }

    #[test]
    fn tile_ids_unique_across_matrices() {
        let mut seen = std::collections::HashSet::new();
        for m in 0..3 {
            for i in 0..4 {
                for j in 0..4 {
                    assert!(seen.insert(tile_id(m, 4, i, j)));
                }
            }
        }
    }

    #[test]
    fn single_thread_runs_in_order() {
        let log = Mutex::new(Vec::new());
        let tasks: Vec<Task> = (0..10)
            .map(|i| {
                let log = &log;
                Task::new(vec![0], move |_| log.lock().unwrap().push(i))
            })
            .collect();
        execute(tasks, 1, 1);
        assert_eq!(*log.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }
}
