//! Blocking client for the `pald-serve` wire protocol — the library
//! surface `paldx loadgen` and the loopback end-to-end tests drive.
//!
//! One request is in flight per client at a time, so responses are
//! matched by request id on a plain blocking socket; error frames come
//! back as typed [`PaldError`] values ([`wire_error_to_pald`]) with
//! retriability preserved — callers distinguish a load-shed reject
//! (back off and retry) from a hard failure exactly as local callers
//! do.

use std::io::Write;
use std::net::TcpStream;

use crate::core::Mat;
use crate::pald::error::PaldError;

use super::proto::{
    decode_response, encode_request, read_frame, wire_error_to_pald, FrameRead, Request,
    Response, WireConfig, DEFAULT_MAX_FRAME,
};

/// A blocking `pald-serve` connection.
pub struct ServeClient {
    stream: TcpStream,
    next_id: u64,
    max_frame: usize,
}

impl ServeClient {
    /// Connect to a server.
    pub fn connect(addr: &str) -> std::io::Result<ServeClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(ServeClient { stream, next_id: 1, max_frame: DEFAULT_MAX_FRAME })
    }

    /// Send one request and block for its response frame.  Server-side
    /// failures come back as [`Response::Error`]; use the typed
    /// wrappers ([`ServeClient::compute`] etc.) to surface them as
    /// [`PaldError`].
    pub fn request(&mut self, req: &Request) -> Result<Response, PaldError> {
        let id = self.next_id;
        self.next_id += 1;
        self.stream
            .write_all(&encode_request(id, req))
            .map_err(|e| PaldError::protocol(format!("send failed: {e}")))?;
        loop {
            match read_frame(&mut self.stream, self.max_frame)? {
                FrameRead::Frame(raw) => {
                    if raw.request_id != id {
                        // A stale frame from an earlier abandoned
                        // request; skip it.
                        continue;
                    }
                    return decode_response(&raw);
                }
                FrameRead::Eof => {
                    return Err(PaldError::protocol("server closed the connection"))
                }
                FrameRead::Idle => continue,
            }
        }
    }

    fn expect_err(resp: Response) -> PaldError {
        match resp {
            Response::Error { code, info, detail } => wire_error_to_pald(code, info, detail),
            other => PaldError::protocol(format!("unexpected response frame {other:?}")),
        }
    }

    /// One-shot cohesion compute.
    pub fn compute(&mut self, cfg: &WireConfig, matrix: &Mat) -> Result<Mat, PaldError> {
        let resp =
            self.request(&Request::Compute { cfg: cfg.clone(), matrix: matrix.clone() })?;
        match resp {
            Response::Cohesion { matrix } => Ok(matrix),
            other => Err(Self::expect_err(other)),
        }
    }

    /// Explicit batch compute; outputs are in input order.
    pub fn compute_batch(
        &mut self,
        cfg: &WireConfig,
        matrices: Vec<Mat>,
    ) -> Result<Vec<Mat>, PaldError> {
        let resp = self.request(&Request::ComputeBatch { cfg: cfg.clone(), matrices })?;
        match resp {
            Response::Batch { matrices } => Ok(matrices),
            other => Err(Self::expect_err(other)),
        }
    }

    /// Open a streaming session; returns `(session_id, n)`.
    pub fn session_open(&mut self, cfg: &WireConfig, seed: &Mat) -> Result<(u64, u32), PaldError> {
        let resp =
            self.request(&Request::SessionOpen { cfg: cfg.clone(), seed: seed.clone() })?;
        match resp {
            Response::SessionOpened { session, n } => Ok((session, n)),
            other => Err(Self::expect_err(other)),
        }
    }

    /// Insert a point into a streaming session; returns
    /// `(n_after, index)`.
    pub fn session_insert(&mut self, session: u64, row: &[f32]) -> Result<(u32, u32), PaldError> {
        let resp = self.request(&Request::SessionInsert { session, row: row.to_vec() })?;
        match resp {
            Response::Updated { n, index } => Ok((n, index)),
            other => Err(Self::expect_err(other)),
        }
    }

    /// Remove a point from a streaming session; returns
    /// `(n_after, index)`.
    pub fn session_remove(&mut self, session: u64, index: u32) -> Result<(u32, u32), PaldError> {
        let resp = self.request(&Request::SessionRemove { session, index })?;
        match resp {
            Response::Updated { n, index } => Ok((n, index)),
            other => Err(Self::expect_err(other)),
        }
    }

    /// The session's current cohesion matrix.
    pub fn session_query(&mut self, session: u64) -> Result<Mat, PaldError> {
        let resp = self.request(&Request::SessionQuery { session })?;
        match resp {
            Response::Cohesion { matrix } => Ok(matrix),
            other => Err(Self::expect_err(other)),
        }
    }

    /// Close a streaming session.
    pub fn session_close(&mut self, session: u64) -> Result<(), PaldError> {
        let resp = self.request(&Request::SessionClose { session })?;
        match resp {
            Response::Closed => Ok(()),
            other => Err(Self::expect_err(other)),
        }
    }

    /// Fetch the plaintext metrics scrape.
    pub fn stats(&mut self) -> Result<String, PaldError> {
        let resp = self.request(&Request::Stats)?;
        match resp {
            Response::Stats { text } => Ok(text),
            other => Err(Self::expect_err(other)),
        }
    }

    /// Ask the server to drain (graceful shutdown).
    pub fn shutdown(&mut self) -> Result<(), PaldError> {
        let resp = self.request(&Request::Shutdown)?;
        match resp {
            Response::ShuttingDown => Ok(()),
            other => Err(Self::expect_err(other)),
        }
    }
}
