//! Blocking clients for the `pald-serve` wire protocol — the library
//! surface `paldx loadgen`, the router's backend pool, and the loopback
//! end-to-end tests drive.
//!
//! One request is in flight per client at a time, so responses are
//! matched by request id on a plain blocking socket; error frames come
//! back as typed [`PaldError`] values ([`wire_error_to_pald`]) with
//! retriability preserved — callers distinguish a load-shed reject
//! (back off and retry) from a hard failure exactly as local callers
//! do.
//!
//! [`ReconnectClient`] wraps [`ServeClient`] with the retry loop the
//! protocol was designed for: exponential backoff with deterministic
//! seeded jitter ([`RetryPolicy`]), driven by
//! [`ErrorCode::retriable`](super::proto::ErrorCode::retriable) on
//! error frames and by transport failures (it re-dials the same
//! address), under a capped budget that exhausts into the typed
//! [`PaldError::RetriesExhausted`].

use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

use crate::core::Mat;
use crate::pald::error::PaldError;

use super::admission::Deadline;
use super::proto::{
    decode_response, encode_request, read_frame, wire_error_to_pald, FrameRead, Request,
    Response, WireConfig, DEFAULT_MAX_FRAME,
};

/// Read-poll granularity for deadline-bounded requests
/// ([`ServeClient::request_before`]).
const POLL: Duration = Duration::from_millis(250);

/// A blocking `pald-serve` connection.
pub struct ServeClient {
    stream: TcpStream,
    next_id: u64,
    max_frame: usize,
}

impl ServeClient {
    /// Connect to a server.
    pub fn connect(addr: &str) -> std::io::Result<ServeClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        // Reads poll at a fixed cadence so deadline-bounded requests
        // ([`ServeClient::request_before`]) can observe their deadline;
        // plain `request` treats the poll as an idle tick and keeps
        // waiting, so blocking callers see no behavior change.
        stream.set_read_timeout(Some(POLL))?;
        Ok(ServeClient { stream, next_id: 1, max_frame: DEFAULT_MAX_FRAME })
    }

    /// Send one request and block for its response frame.  Server-side
    /// failures come back as [`Response::Error`]; use the typed
    /// wrappers ([`ServeClient::compute`] etc.) to surface them as
    /// [`PaldError`].
    pub fn request(&mut self, req: &Request) -> Result<Response, PaldError> {
        self.request_before(req, None)
    }

    /// [`ServeClient::request`] bounded by a deadline: if no response
    /// frame has *started* arriving when `deadline` lapses, gives up
    /// with the deadline's typed [`PaldError::Timeout`].  `None` waits
    /// indefinitely.  The router's relay and health probes use this so
    /// a hung backend cannot absorb a caller forever.
    pub fn request_before(
        &mut self,
        req: &Request,
        deadline: Option<&Deadline>,
    ) -> Result<Response, PaldError> {
        let id = self.next_id;
        self.next_id += 1;
        self.stream
            .write_all(&encode_request(id, req))
            .map_err(|e| PaldError::protocol(format!("send failed: {e}")))?;
        loop {
            match read_frame(&mut self.stream, self.max_frame)? {
                FrameRead::Frame(raw) => {
                    if raw.request_id != id {
                        // A stale frame from an earlier abandoned
                        // request; skip it.
                        continue;
                    }
                    return decode_response(&raw);
                }
                FrameRead::Eof => {
                    return Err(PaldError::protocol("server closed the connection"))
                }
                FrameRead::Idle => {
                    if let Some(d) = deadline {
                        if d.expired() {
                            return Err(d.timeout_error());
                        }
                    }
                }
            }
        }
    }

    fn expect_err(resp: Response) -> PaldError {
        match resp {
            Response::Error { code, info, detail } => wire_error_to_pald(code, info, detail),
            other => PaldError::protocol(format!("unexpected response frame {other:?}")),
        }
    }

    /// One-shot cohesion compute.
    pub fn compute(&mut self, cfg: &WireConfig, matrix: &Mat) -> Result<Mat, PaldError> {
        let resp =
            self.request(&Request::Compute { cfg: cfg.clone(), matrix: matrix.clone() })?;
        match resp {
            Response::Cohesion { matrix } => Ok(matrix),
            other => Err(Self::expect_err(other)),
        }
    }

    /// Explicit batch compute; outputs are in input order.
    pub fn compute_batch(
        &mut self,
        cfg: &WireConfig,
        matrices: Vec<Mat>,
    ) -> Result<Vec<Mat>, PaldError> {
        let resp = self.request(&Request::ComputeBatch { cfg: cfg.clone(), matrices })?;
        match resp {
            Response::Batch { matrices } => Ok(matrices),
            other => Err(Self::expect_err(other)),
        }
    }

    /// Open a streaming session; returns `(session_id, n)`.
    pub fn session_open(&mut self, cfg: &WireConfig, seed: &Mat) -> Result<(u64, u32), PaldError> {
        let resp =
            self.request(&Request::SessionOpen { cfg: cfg.clone(), seed: seed.clone() })?;
        match resp {
            Response::SessionOpened { session, n } => Ok((session, n)),
            other => Err(Self::expect_err(other)),
        }
    }

    /// Insert a point into a streaming session; returns
    /// `(n_after, index)`.
    pub fn session_insert(&mut self, session: u64, row: &[f32]) -> Result<(u32, u32), PaldError> {
        let resp = self.request(&Request::SessionInsert { session, row: row.to_vec() })?;
        match resp {
            Response::Updated { n, index } => Ok((n, index)),
            other => Err(Self::expect_err(other)),
        }
    }

    /// Remove a point from a streaming session; returns
    /// `(n_after, index)`.
    pub fn session_remove(&mut self, session: u64, index: u32) -> Result<(u32, u32), PaldError> {
        let resp = self.request(&Request::SessionRemove { session, index })?;
        match resp {
            Response::Updated { n, index } => Ok((n, index)),
            other => Err(Self::expect_err(other)),
        }
    }

    /// The session's current cohesion matrix.
    pub fn session_query(&mut self, session: u64) -> Result<Mat, PaldError> {
        let resp = self.request(&Request::SessionQuery { session })?;
        match resp {
            Response::Cohesion { matrix } => Ok(matrix),
            other => Err(Self::expect_err(other)),
        }
    }

    /// Close a streaming session.
    pub fn session_close(&mut self, session: u64) -> Result<(), PaldError> {
        let resp = self.request(&Request::SessionClose { session })?;
        match resp {
            Response::Closed => Ok(()),
            other => Err(Self::expect_err(other)),
        }
    }

    /// Fetch the plaintext metrics scrape.
    pub fn stats(&mut self) -> Result<String, PaldError> {
        let resp = self.request(&Request::Stats)?;
        match resp {
            Response::Stats { text } => Ok(text),
            other => Err(Self::expect_err(other)),
        }
    }

    /// Ask the server to drain (graceful shutdown).
    pub fn shutdown(&mut self) -> Result<(), PaldError> {
        let resp = self.request(&Request::Shutdown)?;
        match resp {
            Response::ShuttingDown => Ok(()),
            other => Err(Self::expect_err(other)),
        }
    }
}

// ---------------------------------------------------------------------
// Reconnecting client (retry with backoff)
// ---------------------------------------------------------------------

/// SplitMix64: the jitter source for [`RetryPolicy::backoff_ms`] —
/// deterministic per `(seed, attempt)`, so retry schedules are
/// reproducible in tests while still decorrelating across clients.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Retry schedule for [`ReconnectClient`]: capped exponential backoff
/// with deterministic seeded jitter.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Retries beyond the first attempt (`0` = single attempt).
    pub max_retries: u32,
    /// Backoff before the first retry, in milliseconds (doubles per
    /// retry).
    pub base_ms: u64,
    /// Backoff ceiling in milliseconds.
    pub cap_ms: u64,
    /// Jitter seed: two policies with the same seed sleep identically.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 3, base_ms: 10, cap_ms: 1_000, seed: 0x5eed }
    }
}

impl RetryPolicy {
    /// The sleep before retry number `attempt` (0-based): exponential
    /// `base_ms << attempt` capped at `cap_ms`, jittered into
    /// `[half, full]` by a SplitMix64 draw on `(seed, attempt)` — a
    /// pure function, so the schedule is reproducible.
    pub fn backoff_ms(&self, attempt: u32) -> u64 {
        let full = self
            .base_ms
            .max(1)
            .saturating_mul(1u64 << attempt.min(20))
            .min(self.cap_ms.max(1));
        let half = full / 2;
        let span = full - half + 1;
        half + splitmix64(self.seed ^ ((attempt as u64) << 32)) % span
    }
}

/// A [`ServeClient`] that re-dials its address and retries under a
/// [`RetryPolicy`] — the ROADMAP-named reconnecting client.
///
/// Two failure classes drive a retry:
///
/// * a **retriable error frame** (`Overloaded` / `Draining`, per
///   [`ErrorCode::retriable`](super::proto::ErrorCode::retriable)) —
///   the connection is healthy, so only the backoff sleep applies;
/// * a **transport failure** (dial refused, connection died, frame
///   truncated mid-body) — the connection is dropped and re-dialed
///   before the next attempt.
///
/// Non-retriable error frames are returned immediately: they answer
/// the request.  When the budget runs out the typed
/// [`PaldError::RetriesExhausted`] reports the attempt count and the
/// last failure.  Connections are dialed lazily, so constructing one
/// of these never blocks.
pub struct ReconnectClient {
    addr: String,
    policy: RetryPolicy,
    inner: Option<ServeClient>,
    dials: u64,
    retries_total: u64,
    last_call_retries: u32,
}

impl ReconnectClient {
    /// Client for `addr` under `policy`; does not connect yet.
    pub fn new(addr: impl Into<String>, policy: RetryPolicy) -> ReconnectClient {
        ReconnectClient {
            addr: addr.into(),
            policy,
            inner: None,
            dials: 0,
            retries_total: 0,
            last_call_retries: 0,
        }
    }

    /// The address this client (re-)dials.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Is a connection currently established?
    pub fn is_connected(&self) -> bool {
        self.inner.is_some()
    }

    /// Times this client has dialed (first connect included).
    pub fn dials(&self) -> u64 {
        self.dials
    }

    /// Retries performed over this client's lifetime.
    pub fn retries_total(&self) -> u64 {
        self.retries_total
    }

    /// Retries the most recent `*_with_retry` call needed (`0` = it
    /// succeeded first try).  Loadgen uses this to count
    /// retried-then-succeeded requests separately from sheds.
    pub fn last_call_retries(&self) -> u32 {
        self.last_call_retries
    }

    fn ensure(&mut self) -> Result<&mut ServeClient, PaldError> {
        if self.inner.is_none() {
            let c = ServeClient::connect(&self.addr)
                .map_err(|e| PaldError::protocol(format!("connect {} failed: {e}", self.addr)))?;
            self.dials += 1;
            self.inner = Some(c);
        }
        Ok(self.inner.as_mut().expect("just ensured"))
    }

    /// One attempt, no retries: dial if disconnected, send, wait
    /// (bounded by `deadline` when given).  Transport failures drop the
    /// connection so the next attempt re-dials.  The router's relay
    /// uses this and performs its *own* retries across backends.
    pub fn request_once(
        &mut self,
        req: &Request,
        deadline: Option<&Deadline>,
    ) -> Result<Response, PaldError> {
        let r = self.ensure().and_then(|c| c.request_before(req, deadline));
        if matches!(r, Err(PaldError::Protocol { .. })) {
            self.inner = None;
        }
        r
    }

    /// Send under the retry policy: backoff-and-retry on retriable
    /// error frames and transport failures, give up with
    /// [`PaldError::RetriesExhausted`] when the budget is spent.  Any
    /// other response (success or non-retriable error frame) is
    /// returned as-is.
    pub fn request_with_retry(&mut self, req: &Request) -> Result<Response, PaldError> {
        self.last_call_retries = 0;
        let mut last: Option<String> = None;
        for attempt in 0..=self.policy.max_retries {
            if attempt > 0 {
                std::thread::sleep(Duration::from_millis(self.policy.backoff_ms(attempt - 1)));
                self.retries_total += 1;
                self.last_call_retries += 1;
            }
            match self.request_once(req, None) {
                Ok(Response::Error { code, info, detail }) if code.retriable() => {
                    last = Some(wire_error_to_pald(code, info, detail).to_string());
                }
                Ok(resp) => return Ok(resp),
                Err(e @ PaldError::Protocol { .. }) => last = Some(e.to_string()),
                Err(other) => return Err(other),
            }
        }
        Err(PaldError::RetriesExhausted {
            attempts: self.policy.max_retries + 1,
            last: last.unwrap_or_else(|| "no attempt recorded".into()),
        })
    }

    /// One-shot cohesion compute under the retry policy.
    pub fn compute_with_retry(
        &mut self,
        cfg: &WireConfig,
        matrix: &Mat,
    ) -> Result<Mat, PaldError> {
        let resp = self
            .request_with_retry(&Request::Compute { cfg: cfg.clone(), matrix: matrix.clone() })?;
        match resp {
            Response::Cohesion { matrix } => Ok(matrix),
            other => Err(ServeClient::expect_err(other)),
        }
    }

    /// Metrics scrape under the retry policy.
    pub fn stats_with_retry(&mut self) -> Result<String, PaldError> {
        let resp = self.request_with_retry(&Request::Stats)?;
        match resp {
            Response::Stats { text } => Ok(text),
            other => Err(ServeClient::expect_err(other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_exponentially_caps_and_is_deterministic() {
        let p = RetryPolicy { max_retries: 8, base_ms: 10, cap_ms: 200, seed: 7 };
        let q = RetryPolicy { max_retries: 8, base_ms: 10, cap_ms: 200, seed: 7 };
        for a in 0..8 {
            // Deterministic per (seed, attempt).
            assert_eq!(p.backoff_ms(a), q.backoff_ms(a), "attempt {a}");
            // Jitter stays inside [full/2, full] where full = min(base << a, cap).
            let full = (10u64 << a).min(200);
            let b = p.backoff_ms(a);
            assert!(b >= full / 2 && b <= full, "attempt {a}: {b} not in [{}, {full}]", full / 2);
        }
        // Attempts past the cap all land in the cap's jitter band.
        assert!(p.backoff_ms(30) >= 100 && p.backoff_ms(30) <= 200);
        // Different seeds decorrelate (with overwhelming probability
        // some attempt differs).
        let r = RetryPolicy { seed: 8, ..p };
        assert!((0..8).any(|a| r.backoff_ms(a) != p.backoff_ms(a)));
    }

    #[test]
    fn reconnect_client_is_lazy_and_exhausts_into_typed_error() {
        // Nothing listens on this address (port 1 is never bound in CI);
        // construction must not dial, and the retry loop must exhaust
        // into RetriesExhausted carrying the attempt count.
        let mut c = ReconnectClient::new(
            "127.0.0.1:1",
            RetryPolicy { max_retries: 2, base_ms: 1, cap_ms: 2, seed: 1 },
        );
        assert!(!c.is_connected());
        assert_eq!(c.dials(), 0);
        let err = c.request_with_retry(&Request::Stats).unwrap_err();
        match err {
            PaldError::RetriesExhausted { attempts, ref last } => {
                assert_eq!(attempts, 3);
                assert!(last.contains("connect"), "{last}");
            }
            other => panic!("expected RetriesExhausted, got {other}"),
        }
        assert_eq!(c.retries_total(), 2);
        assert_eq!(c.last_call_retries(), 2);
    }
}
