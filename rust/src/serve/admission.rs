//! Admission control for `pald-serve`: bounded queueing, per-request
//! deadlines, and load shedding (DESIGN.md §12).
//!
//! The controller is deliberately tiny — three atomics and a clock — so
//! every decision it makes is explainable:
//!
//! * **Bounded queue.**  [`Admission::try_admit`] reserves a slot with a
//!   lock-free `fetch_update` (no overshoot under contention); when the
//!   queue is full the request is rejected with
//!   [`PaldError::Overloaded`], a *retriable* code, instead of growing
//!   an unbounded backlog whose tail latency nobody asked for.
//! * **Per-request deadlines.**  Each admitted request carries a
//!   [`Deadline`]; the dispatcher drops requests whose deadline lapsed
//!   while queued (answering [`PaldError::Timeout`]) rather than burning
//!   worker time on an answer the client has stopped waiting for.
//! * **Draining.**  Once [`Admission::start_drain`] is called (SIGTERM /
//!   SIGINT / in-band `SHUTDOWN` frame), new work is rejected with
//!   [`PaldError::Draining`] — also retriable, so well-behaved clients
//!   fail over — while already-admitted work runs to completion.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use crate::pald::error::PaldError;

/// Absolute per-request deadline, resolved at admission time.
#[derive(Clone, Copy, Debug)]
pub struct Deadline {
    at: Option<Instant>,
    /// The millisecond budget the deadline was built from (carried so
    /// timeout errors can report it).
    pub budget_ms: u64,
}

impl Deadline {
    /// Deadline `ms` milliseconds from now; `ms == 0` means no deadline.
    pub fn in_ms(ms: u64) -> Deadline {
        Deadline {
            at: (ms > 0).then(|| Instant::now() + Duration::from_millis(ms)),
            budget_ms: ms,
        }
    }

    /// Has the deadline lapsed?
    pub fn expired(&self) -> bool {
        self.at.is_some_and(|at| Instant::now() >= at)
    }

    /// The typed error a lapsed deadline maps to.
    pub fn timeout_error(&self) -> PaldError {
        PaldError::Timeout { deadline_ms: self.budget_ms }
    }
}

/// A queue slot held by an admitted request; must be handed back via
/// [`Admission::release`] exactly once (the serving layer releases when
/// the response — success or typed error — is queued to the writer).
#[derive(Debug)]
#[must_use = "an admitted slot must be released or the queue leaks capacity"]
pub struct Ticket {
    /// Deadline resolved at admission.
    pub deadline: Deadline,
}

/// Shared admission state (one per server, behind an `Arc`).
pub struct Admission {
    queued: AtomicUsize,
    queue_cap: usize,
    draining: AtomicBool,
    shed: AtomicU64,
    timed_out: AtomicU64,
    admitted: AtomicU64,
}

impl Admission {
    /// Controller admitting at most `queue_cap` concurrently-held
    /// tickets.
    pub fn new(queue_cap: usize) -> Admission {
        Admission {
            queued: AtomicUsize::new(0),
            queue_cap: queue_cap.max(1),
            draining: AtomicBool::new(false),
            shed: AtomicU64::new(0),
            timed_out: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
        }
    }

    /// Try to admit a request with a `deadline_ms` budget (`0` = use
    /// `default_deadline_ms`).  Rejections are typed and retriable:
    /// [`PaldError::Draining`] while shutting down,
    /// [`PaldError::Overloaded`] when the queue is full.
    pub fn try_admit(&self, deadline_ms: u64, default_deadline_ms: u64) -> Result<Ticket, PaldError> {
        if self.draining.load(Ordering::Acquire) {
            self.shed.fetch_add(1, Ordering::Relaxed);
            return Err(PaldError::Draining);
        }
        // fetch_update never overshoots the cap, unlike a blind
        // fetch_add/check/undo, which can transiently reject admissible
        // requests under contention.
        let reserved = self
            .queued
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |q| {
                (q < self.queue_cap).then_some(q + 1)
            });
        if reserved.is_err() {
            self.shed.fetch_add(1, Ordering::Relaxed);
            return Err(PaldError::Overloaded { queued: self.queue_cap, cap: self.queue_cap });
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        let ms = if deadline_ms == 0 { default_deadline_ms } else { deadline_ms };
        Ok(Ticket { deadline: Deadline::in_ms(ms) })
    }

    /// Hand a ticket's queue slot back.
    pub fn release(&self, ticket: Ticket) {
        drop(ticket);
        self.queued.fetch_sub(1, Ordering::AcqRel);
    }

    /// Record a queued-past-deadline drop (metrics only; the slot is
    /// released separately).
    pub fn note_timeout(&self) {
        self.timed_out.fetch_add(1, Ordering::Relaxed);
    }

    /// Enter drain mode: all future [`Admission::try_admit`] calls are
    /// rejected with [`PaldError::Draining`].  Idempotent.
    pub fn start_drain(&self) {
        self.draining.store(true, Ordering::Release);
    }

    /// Is the server draining?
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// Tickets currently held.
    pub fn queued(&self) -> usize {
        self.queued.load(Ordering::Acquire)
    }

    /// Queue capacity.
    pub fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    /// Counters for the scrape endpoint: `(admitted, shed, timed_out)`.
    pub fn counters(&self) -> (u64, u64, u64) {
        (
            self.admitted.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.timed_out.load(Ordering::Relaxed),
        )
    }
}

/// Concurrency limit for compute dispatch, derived from the planner's
/// thread budget: with `threads_per_job` threads handed to each job's
/// parallel kernels, running more than `host_threads / threads_per_job`
/// jobs at once oversubscribes cores and inflates every job's latency.
pub fn inflight_limit(host_threads: usize, threads_per_job: usize) -> usize {
    (host_threads / threads_per_job.max(1)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_until_cap_then_sheds_retriable() {
        let a = Admission::new(2);
        let t1 = a.try_admit(0, 100).unwrap();
        let _t2 = a.try_admit(0, 100).unwrap();
        let err = a.try_admit(0, 100).unwrap_err();
        assert!(err.is_retriable(), "{err}");
        assert!(matches!(err, PaldError::Overloaded { cap: 2, .. }));
        a.release(t1);
        assert_eq!(a.queued(), 1);
        let _t3 = a.try_admit(0, 100).unwrap();
        let (admitted, shed, _) = a.counters();
        assert_eq!((admitted, shed), (3, 1));
    }

    #[test]
    fn draining_rejects_with_retriable_code() {
        let a = Admission::new(8);
        a.start_drain();
        let err = a.try_admit(0, 100).unwrap_err();
        assert!(matches!(err, PaldError::Draining));
        assert!(err.is_retriable());
    }

    #[test]
    fn deadlines_resolve_defaults_and_expire() {
        let a = Admission::new(8);
        let t = a.try_admit(0, 1).unwrap();
        assert_eq!(t.deadline.budget_ms, 1);
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.deadline.expired());
        assert!(matches!(t.deadline.timeout_error(), PaldError::Timeout { deadline_ms: 1 }));
        let t2 = a.try_admit(0, 0).unwrap();
        assert!(!t2.deadline.expired(), "no deadline never expires");
        a.release(t);
        a.release(t2);
    }

    #[test]
    fn concurrent_admission_never_overshoots_cap() {
        let a = Admission::new(16);
        let peak = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..200 {
                        if let Ok(t) = a.try_admit(0, 0) {
                            let q = a.queued();
                            peak.fetch_max(q, Ordering::Relaxed);
                            assert!(q <= 16, "queue overshot: {q}");
                            a.release(t);
                        }
                    }
                });
            }
        });
        assert_eq!(a.queued(), 0);
        assert!(peak.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn inflight_limit_tracks_thread_budget() {
        assert_eq!(inflight_limit(8, 2), 4);
        assert_eq!(inflight_limit(8, 16), 1);
        assert_eq!(inflight_limit(8, 0), 8);
    }
}
