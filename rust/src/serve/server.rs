//! The `pald-serve` TCP server: std-only threads + channels, no async
//! runtime (DESIGN.md §12).
//!
//! Thread topology:
//!
//! ```text
//! acceptor ──► per-connection reader ──► dispatcher ──► worker pool
//!                    │    ▲                                  │
//!                    ▼    │ (typed error / inline replies)   │
//!              per-connection writer ◄───────────────────────┘
//! ```
//!
//! * The **acceptor** polls a non-blocking listener; each connection
//!   gets a reader thread and a writer thread (responses funnel through
//!   one mpsc channel per connection, so frames never interleave).
//!   The first 4 bytes of a connection are sniffed: `b"GET "` serves a
//!   plaintext metrics scrape over HTTP and closes; anything else is a
//!   frame length prefix.
//! * **Readers** decode frames.  Cheap requests (stats, shutdown,
//!   session ops) run inline under an admission ticket; compute
//!   requests are admitted ([`Admission`]) and forwarded to the
//!   dispatcher.  Malformed frames get a typed
//!   [`ErrorCode::Protocol`](super::proto::ErrorCode) reply and the
//!   connection closes.
//! * The **dispatcher** stages one-shot computes by [`ShapeKey`],
//!   coalescing same-shape requests that arrive within the batch
//!   window into a single group, expires queued-past-deadline requests
//!   with typed timeouts, reaps idle streaming sessions, and feeds the
//!   worker pool while respecting the inflight limit derived from the
//!   thread budget ([`inflight_limit`]).
//! * **Workers** check a warm [`Session`] out of the [`WarmPool`], run
//!   the group through one `compute_batch_refs` call (bit-identical to
//!   serving the requests one at a time), record work-aware
//!   [`JobMetrics`], and check the session back in.
//!
//! Graceful shutdown: SIGINT/SIGTERM (via [`install_signal_handlers`]),
//! an in-band `SHUTDOWN` frame, or [`ServerHandle::shutdown`] all start
//! a drain — new work is rejected with the retriable
//! [`PaldError::Draining`], staged and in-flight work completes, then
//! every thread exits and [`ServerHandle::join`] returns the final
//! metrics scrape.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{JobMetrics, MetricsRegistry};
use crate::core::Mat;
use crate::pald::api::available_threads;
use crate::pald::error::PaldError;
use crate::pald::input::DistanceInput;
use crate::pald::Session;

use super::admission::{inflight_limit, Admission, Ticket};
use super::pool::{ShapeKey, WarmPool};
use super::proto::{
    decode_request, encode_response, pald_error_to_wire, read_frame_after_len, FrameRead,
    RawFrame, Request, Response, DEFAULT_MAX_FRAME,
};
use super::stream::StreamSessions;

// ---------------------------------------------------------------------
// Signals
// ---------------------------------------------------------------------

/// Process-wide shutdown flag set by SIGINT/SIGTERM (and nothing else).
static SIGNAL_SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_signal(_sig: i32) {
    // Only async-signal-safe work here: one atomic store.
    SIGNAL_SHUTDOWN.store(true, Ordering::Release);
}

/// Install SIGINT/SIGTERM handlers that flip the process-wide shutdown
/// flag ([`shutdown_requested`]) — `paldx serve` drains and exits 0,
/// `paldx stream` stops replaying and still writes its report.  No-op
/// off Unix.  Idempotent.
pub fn install_signal_handlers() {
    #[cfg(unix)]
    {
        // libstd already links libc; declare `signal` directly instead
        // of growing a dependency for two signal numbers.
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGINT, on_signal as usize);
            signal(SIGTERM, on_signal as usize);
        }
    }
}

/// Has SIGINT/SIGTERM been received since
/// [`install_signal_handlers`]?
pub fn shutdown_requested() -> bool {
    SIGNAL_SHUTDOWN.load(Ordering::Acquire)
}

// ---------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------

/// `pald-serve` server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address (`"host:0"` picks an ephemeral port — see
    /// [`ServerHandle::addr`]).
    pub addr: String,
    /// Admission queue capacity: requests concurrently held anywhere in
    /// the server (staged, inflight, or inline).  Beyond it, requests
    /// are shed with the retriable [`PaldError::Overloaded`].
    pub queue_cap: usize,
    /// Deadline applied to requests that don't carry their own (`0` =
    /// no deadline).
    pub default_deadline_ms: u64,
    /// Warm-pool memory cap in bytes ([`WarmPool`] LRU-evicts past it).
    pub mem_cap_bytes: usize,
    /// Streaming sessions idle longer than this are reaped.
    pub idle_timeout_ms: u64,
    /// How long the dispatcher holds a one-shot open for same-shape
    /// coalescing (`0` = dispatch on the next tick).
    pub batch_window_ms: u64,
    /// Worker threads handed to each job's parallel kernels.
    pub threads_per_job: usize,
    /// Compute workers (`0` = derive from the host thread budget:
    /// [`inflight_limit`]`(available_threads(), threads_per_job)`).
    pub workers: usize,
    /// Re-anchor cadence for streaming sessions
    /// ([`ReanchorPolicy::EveryN`](crate::pald::ReanchorPolicy); `0` =
    /// never).
    pub reanchor_every: u64,
    /// Strict per-item input validation before compute (symmetry, zero
    /// diagonal, value range) — one bad matrix in a coalesced group
    /// fails alone, not the group.
    pub validate: bool,
    /// Frame size cap (bytes).
    pub max_frame: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7465".into(),
            queue_cap: 256,
            default_deadline_ms: 2_000,
            mem_cap_bytes: 256 << 20,
            idle_timeout_ms: 30_000,
            batch_window_ms: 2,
            threads_per_job: 1,
            workers: 0,
            reanchor_every: 1_024,
            validate: true,
            max_frame: DEFAULT_MAX_FRAME,
        }
    }
}

// ---------------------------------------------------------------------
// Shared state
// ---------------------------------------------------------------------

struct Shared {
    cfg: ServeConfig,
    admission: Admission,
    pool: WarmPool,
    streams: StreamSessions,
    metrics: MetricsRegistry,
    /// Drain requested (signal, `SHUTDOWN` frame, or handle).
    drain: AtomicBool,
    /// Everything winds down: acceptor and readers exit.
    stop: AtomicBool,
    /// Compute groups currently running on workers.
    inflight: AtomicUsize,
    /// Connections accepted over the server's lifetime.
    conns: AtomicU64,
    /// Wall-clock start time (Unix ms) — the scrape's identity gauge, so
    /// an aggregating front-tier can tell a restart from a stale scrape.
    start_ms: u64,
    /// Resolved compute-worker count (after the `workers == 0` →
    /// thread-budget derivation), exposed on the scrape so fleet
    /// aggregation needs no per-shard config duplication.
    workers: usize,
}

impl Shared {
    fn drain_requested(&self) -> bool {
        self.drain.load(Ordering::Acquire) || shutdown_requested()
    }

    fn request_drain(&self) {
        self.drain.store(true, Ordering::Release);
        self.admission.start_drain();
    }

    /// The full plaintext scrape: job metrics plus serving counters.
    fn scrape(&self) -> String {
        let mut out = self.metrics.scrape();
        let (admitted, shed, timed_out) = self.admission.counters();
        let (hits, misses, evictions) = self.pool.counters();
        let (opened, closed, updates, reaped) = self.streams.counters();
        out.push_str(&format!("paldx_serve_admitted_total {admitted}\n"));
        out.push_str(&format!("paldx_serve_shed_total {shed}\n"));
        out.push_str(&format!("paldx_serve_timeout_total {timed_out}\n"));
        out.push_str(&format!("paldx_serve_queue_depth {}\n", self.admission.queued()));
        out.push_str(&format!("paldx_serve_draining {}\n", u8::from(self.admission.is_draining())));
        out.push_str(&format!("paldx_serve_connections_total {}\n", self.conns.load(Ordering::Relaxed)));
        out.push_str(&format!("paldx_pool_hits_total {hits}\n"));
        out.push_str(&format!("paldx_pool_misses_total {misses}\n"));
        out.push_str(&format!("paldx_pool_evictions_total {evictions}\n"));
        out.push_str(&format!("paldx_pool_bytes {}\n", self.pool.bytes()));
        out.push_str(&format!("paldx_sessions_opened_total {opened}\n"));
        out.push_str(&format!("paldx_sessions_closed_total {closed}\n"));
        out.push_str(&format!("paldx_sessions_updates_total {updates}\n"));
        out.push_str(&format!("paldx_sessions_reaped_total {reaped}\n"));
        out.push_str(&format!("paldx_sessions_live {}\n", self.streams.len()));
        // Backend availability (DESIGN.md §13): whether the SIMD rungs
        // run on AVX2 here or fall back to the portable lanes.
        out.push_str(&format!(
            "paldx_simd_available {}\n",
            u8::from(crate::pald::simd::simd_available())
        ));
        // Liveness/identity gauges (DESIGN.md §14): a front-tier's
        // aggregated scrape labels shards with these instead of
        // duplicating per-shard config.
        out.push_str("paldx_up 1\n");
        out.push_str(&format!("paldx_server_start_ms {}\n", self.start_ms));
        out.push_str(&format!("paldx_server_workers {}\n", self.workers));
        out.push_str(&format!(
            "paldx_server_threads_per_job {}\n",
            self.cfg.threads_per_job.max(1)
        ));
        out
    }
}

/// A one-shot compute staged for coalescing.
struct OneItem {
    matrix: Mat,
    request_id: u64,
    reply: Sender<Vec<u8>>,
    ticket: Ticket,
    enqueued: Instant,
}

/// Work forwarded from readers to the dispatcher.
enum Work {
    One { key: ShapeKey, item: OneItem },
    Batch { key: ShapeKey, matrices: Vec<Mat>, request_id: u64, reply: Sender<Vec<u8>>, ticket: Ticket },
}

/// A dispatch group handed to the worker pool.
enum GroupJob {
    /// Same-shape one-shots coalesced into one `compute_batch_refs`.
    Coalesced { key: ShapeKey, items: Vec<OneItem> },
    /// An explicit `COMPUTE_BATCH` frame (never merged with others).
    Explicit { key: ShapeKey, matrices: Vec<Mat>, request_id: u64, reply: Sender<Vec<u8>>, ticket: Ticket },
}

fn error_bytes(request_id: u64, e: &PaldError) -> Vec<u8> {
    let (code, info, detail) = pald_error_to_wire(e);
    encode_response(request_id, &Response::Error { code, info, detail })
}

// ---------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------

/// The running server.  Construct with [`Server::start`]; interact via
/// the returned [`ServerHandle`].
pub struct Server;

/// Handle to a running server: its bound address, a drain trigger, and
/// a join that returns once shutdown completes.
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The address the server actually bound (resolves `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Trigger a graceful drain (same path as SIGTERM / the in-band
    /// `SHUTDOWN` frame): new work is rejected with the retriable
    /// [`PaldError::Draining`], in-flight work completes.
    pub fn shutdown(&self) {
        self.shared.request_drain();
    }

    /// Is the server draining?
    pub fn is_draining(&self) -> bool {
        self.shared.admission.is_draining()
    }

    /// Current plaintext metrics scrape.
    pub fn scrape(&self) -> String {
        self.shared.scrape()
    }

    /// Wait for the server to finish draining and every thread to exit;
    /// returns the final metrics scrape (the "flush" of a graceful
    /// shutdown).  Blocks until a drain is triggered by a signal, a
    /// `SHUTDOWN` frame, or [`ServerHandle::shutdown`].
    pub fn join(self) -> String {
        for t in self.threads {
            let _ = t.join();
        }
        self.shared.scrape()
    }
}

impl Server {
    /// Bind `cfg.addr` and spawn the serving threads.
    pub fn start(cfg: ServeConfig) -> std::io::Result<ServerHandle> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let workers = if cfg.workers > 0 {
            cfg.workers
        } else {
            inflight_limit(available_threads(), cfg.threads_per_job)
        };
        let shared = Arc::new(Shared {
            admission: Admission::new(cfg.queue_cap),
            pool: WarmPool::new(cfg.mem_cap_bytes),
            streams: StreamSessions::new(
                Duration::from_millis(cfg.idle_timeout_ms),
                cfg.reanchor_every,
            ),
            metrics: MetricsRegistry::new(),
            drain: AtomicBool::new(false),
            stop: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            conns: AtomicU64::new(0),
            start_ms: std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_millis() as u64)
                .unwrap_or(0),
            workers,
            cfg,
        });

        let (work_tx, work_rx) = mpsc::channel::<Work>();
        let (job_tx, job_rx) = mpsc::channel::<GroupJob>();
        let job_rx = Arc::new(Mutex::new(job_rx));

        let mut threads = Vec::new();
        for w in 0..workers {
            let sh = Arc::clone(&shared);
            let rx = Arc::clone(&job_rx);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("pald-worker-{w}"))
                    .spawn(move || worker_loop(&sh, &rx))?,
            );
        }
        {
            let sh = Arc::clone(&shared);
            threads.push(
                std::thread::Builder::new()
                    .name("pald-dispatch".into())
                    .spawn(move || dispatcher_loop(&sh, work_rx, job_tx, workers))?,
            );
        }
        {
            let sh = Arc::clone(&shared);
            threads.push(
                std::thread::Builder::new()
                    .name("pald-accept".into())
                    .spawn(move || acceptor_loop(&sh, listener, work_tx))?,
            );
        }
        Ok(ServerHandle { addr, shared, threads })
    }
}

// ---------------------------------------------------------------------
// Acceptor + connections
// ---------------------------------------------------------------------

fn acceptor_loop(sh: &Arc<Shared>, listener: TcpListener, work_tx: Sender<Work>) {
    while !sh.stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _)) => {
                sh.conns.fetch_add(1, Ordering::Relaxed);
                let sh = Arc::clone(sh);
                let tx = work_tx.clone();
                // Connection threads are detached: they exit on EOF, on
                // protocol error, or when `stop` flips (their 250 ms
                // read poll observes it).
                let _ = std::thread::Builder::new()
                    .name("pald-conn".into())
                    .spawn(move || connection_loop(&sh, stream, tx));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    // Dropping work_tx here (with every connection eventually closing)
    // lets the dispatcher observe disconnect after the readers exit.
}

enum Prefix {
    Bytes([u8; 4]),
    Eof,
    Idle,
    Dead,
}

/// Read a connection's next 4-byte frame prefix, tolerating read-timeout
/// polls (bounded once the first byte has arrived).
fn read_prefix(r: &mut TcpStream) -> Prefix {
    let mut buf = [0u8; 4];
    let mut got = 0;
    let mut retries = 120usize;
    loop {
        match r.read(&mut buf[got..]) {
            Ok(0) => return if got == 0 { Prefix::Eof } else { Prefix::Dead },
            Ok(m) => {
                got += m;
                if got == 4 {
                    return Prefix::Bytes(buf);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if got == 0 {
                    return Prefix::Idle;
                }
                if retries == 0 {
                    return Prefix::Dead;
                }
                retries -= 1;
            }
            Err(_) => return Prefix::Dead,
        }
    }
}

fn connection_loop(sh: &Arc<Shared>, mut stream: TcpStream, work_tx: Sender<Work>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let (reply_tx, reply_rx) = mpsc::channel::<Vec<u8>>();
    let writer = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let writer_thread = std::thread::Builder::new()
        .name("pald-conn-w".into())
        .spawn(move || writer_loop(writer, reply_rx));

    let mut first = true;
    loop {
        if sh.stop.load(Ordering::Acquire) {
            break;
        }
        match read_prefix(&mut stream) {
            Prefix::Idle => continue,
            Prefix::Eof | Prefix::Dead => break,
            Prefix::Bytes(len4) => {
                if first && &len4 == b"GET " {
                    serve_http_scrape(sh, &mut stream);
                    break;
                }
                first = false;
                match read_frame_after_len(&mut stream, len4, sh.cfg.max_frame) {
                    Ok(FrameRead::Frame(raw)) => {
                        if !handle_frame(sh, &raw, &reply_tx, &work_tx) {
                            break;
                        }
                    }
                    // After-len reads never report Eof/Idle; truncation
                    // is an error.
                    Ok(_) => break,
                    Err(e) => {
                        let _ = reply_tx.send(error_bytes(0, &e));
                        break;
                    }
                }
            }
        }
    }
    // Dropping reply_tx ends the writer after it flushes queued frames.
    drop(reply_tx);
    if let Ok(t) = writer_thread {
        let _ = t.join();
    }
}

fn writer_loop(mut stream: TcpStream, rx: Receiver<Vec<u8>>) {
    for bytes in rx {
        if stream.write_all(&bytes).is_err() {
            break;
        }
    }
    let _ = stream.flush();
}

/// Minimal HTTP/1.0 response for scrape GETs sharing the frame port
/// (the first 4 bytes, `b"GET "`, were already consumed by the sniff).
fn serve_http_scrape(sh: &Shared, stream: &mut TcpStream) {
    // Drain the request head (bounded) so the peer's send completes.
    let mut buf = [0u8; 1024];
    let mut total = 0;
    loop {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(m) => {
                total += m;
                if buf[..m].windows(4).any(|w| w == b"\r\n\r\n") || total > 8192 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let body = sh.scrape();
    let head = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}

/// Handle one decoded frame on the reader thread.  Returns `false` when
/// the connection must close (protocol error).
fn handle_frame(
    sh: &Arc<Shared>,
    raw: &RawFrame,
    reply_tx: &Sender<Vec<u8>>,
    work_tx: &Sender<Work>,
) -> bool {
    let id = raw.request_id;
    let req = match decode_request(raw) {
        Ok(r) => r,
        Err(e) => {
            let _ = reply_tx.send(error_bytes(id, &e));
            return false;
        }
    };
    match req {
        Request::Stats => {
            let _ = reply_tx.send(encode_response(id, &Response::Stats { text: sh.scrape() }));
        }
        Request::Shutdown => {
            sh.request_drain();
            let _ = reply_tx.send(encode_response(id, &Response::ShuttingDown));
        }
        // Closing frees memory — allowed even while draining.
        Request::SessionClose { session } => {
            let resp = match sh.streams.close(session) {
                Ok(()) => Response::Closed,
                Err(e) => {
                    let (code, info, detail) = e.to_wire();
                    Response::Error { code, info, detail }
                }
            };
            let _ = reply_tx.send(encode_response(id, &resp));
        }
        Request::Compute { cfg, matrix } => {
            let ticket = match sh
                .admission
                .try_admit(cfg.deadline_ms as u64, sh.cfg.default_deadline_ms)
            {
                Ok(t) => t,
                Err(e) => {
                    let _ = reply_tx.send(error_bytes(id, &e));
                    return true;
                }
            };
            match ShapeKey::for_request(&cfg, matrix.rows()) {
                Ok(key) => {
                    let item = OneItem {
                        matrix,
                        request_id: id,
                        reply: reply_tx.clone(),
                        ticket,
                        enqueued: Instant::now(),
                    };
                    if work_tx.send(Work::One { key, item }).is_err() {
                        // Dispatcher is gone (shutdown race): shed.
                        let _ = reply_tx.send(error_bytes(id, &PaldError::Draining));
                    }
                }
                Err(e) => {
                    let _ = reply_tx.send(error_bytes(id, &e));
                    sh.admission.release(ticket);
                }
            }
        }
        Request::ComputeBatch { cfg, matrices } => {
            let ticket = match sh
                .admission
                .try_admit(cfg.deadline_ms as u64, sh.cfg.default_deadline_ms)
            {
                Ok(t) => t,
                Err(e) => {
                    let _ = reply_tx.send(error_bytes(id, &e));
                    return true;
                }
            };
            if matrices.is_empty() {
                let _ = reply_tx.send(encode_response(id, &Response::Batch { matrices: vec![] }));
                sh.admission.release(ticket);
                return true;
            }
            match ShapeKey::for_request(&cfg, matrices[0].rows()) {
                Ok(key) => {
                    let work = Work::Batch {
                        key,
                        matrices,
                        request_id: id,
                        reply: reply_tx.clone(),
                        ticket,
                    };
                    if work_tx.send(work).is_err() {
                        let _ = reply_tx.send(error_bytes(id, &PaldError::Draining));
                    }
                }
                Err(e) => {
                    let _ = reply_tx.send(error_bytes(id, &e));
                    sh.admission.release(ticket);
                }
            }
        }
        Request::SessionOpen { cfg, seed } => {
            with_ticket(sh, reply_tx, id, cfg.deadline_ms as u64, |sh| {
                let t0 = Instant::now();
                let r = sh.streams.open(&cfg, &seed, sh.cfg.threads_per_job, sh.cfg.validate);
                match r {
                    Ok((session, n)) => {
                        sh.metrics.record(JobMetrics {
                            n: n as usize,
                            k: cfg.k as usize,
                            algorithm: "incremental".into(),
                            backend: "scalar".into(),
                            seconds: t0.elapsed().as_secs_f64(),
                        });
                        Response::SessionOpened { session, n }
                    }
                    Err(e) => {
                        let (code, info, detail) = e.to_wire();
                        Response::Error { code, info, detail }
                    }
                }
            });
        }
        Request::SessionInsert { session, row } => {
            with_ticket(sh, reply_tx, id, 0, |sh| match sh.streams.insert(session, &row) {
                Ok((n, index)) => Response::Updated { n, index },
                Err(e) => {
                    let (code, info, detail) = e.to_wire();
                    Response::Error { code, info, detail }
                }
            });
        }
        Request::SessionRemove { session, index } => {
            with_ticket(sh, reply_tx, id, 0, |sh| match sh.streams.remove(session, index) {
                Ok((n, index)) => Response::Updated { n, index },
                Err(e) => {
                    let (code, info, detail) = e.to_wire();
                    Response::Error { code, info, detail }
                }
            });
        }
        Request::SessionQuery { session } => {
            with_ticket(sh, reply_tx, id, 0, |sh| {
                let t0 = Instant::now();
                match sh.streams.query(session) {
                    Ok(matrix) => {
                        sh.metrics.record(JobMetrics {
                            n: matrix.rows(),
                            k: 0,
                            algorithm: "incremental".into(),
                            backend: "scalar".into(),
                            seconds: t0.elapsed().as_secs_f64(),
                        });
                        Response::Cohesion { matrix }
                    }
                    Err(e) => {
                        let (code, info, detail) = e.to_wire();
                        Response::Error { code, info, detail }
                    }
                }
            });
        }
    }
    true
}

/// Run an inline (reader-thread) operation under an admission ticket.
fn with_ticket(
    sh: &Shared,
    reply_tx: &Sender<Vec<u8>>,
    id: u64,
    deadline_ms: u64,
    op: impl FnOnce(&Shared) -> Response,
) {
    match sh.admission.try_admit(deadline_ms, sh.cfg.default_deadline_ms) {
        Ok(ticket) => {
            let resp = op(sh);
            let _ = reply_tx.send(encode_response(id, &resp));
            sh.admission.release(ticket);
        }
        Err(e) => {
            let _ = reply_tx.send(error_bytes(id, &e));
        }
    }
}

// ---------------------------------------------------------------------
// Dispatcher
// ---------------------------------------------------------------------

fn dispatcher_loop(
    sh: &Arc<Shared>,
    work_rx: Receiver<Work>,
    job_tx: Sender<GroupJob>,
    inflight_cap: usize,
) {
    let window = Duration::from_millis(sh.cfg.batch_window_ms);
    let tick = Duration::from_millis(sh.cfg.batch_window_ms.clamp(1, 10));
    let mut staged: HashMap<ShapeKey, Vec<OneItem>> = HashMap::new();
    let mut staged_batches: Vec<(ShapeKey, Vec<Mat>, u64, Sender<Vec<u8>>, Ticket)> = Vec::new();
    let mut last_reap = Instant::now();
    let mut disconnected = false;

    loop {
        match work_rx.recv_timeout(tick) {
            Ok(w) => stage(&mut staged, &mut staged_batches, w),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => disconnected = true,
        }
        // Pull everything already queued so one tick sees the whole
        // burst (this is what makes same-shape coalescing effective).
        while let Ok(w) = work_rx.try_recv() {
            stage(&mut staged, &mut staged_batches, w);
        }

        if sh.drain_requested() {
            // Signal-triggered drains funnel through the same path as
            // the in-band SHUTDOWN frame.
            sh.request_drain();
        }
        let draining = sh.admission.is_draining();
        let now = Instant::now();

        // Expire one-shots whose deadline lapsed while staged.
        staged.retain(|_, items| {
            items.retain_mut(|item| {
                if item.ticket.deadline.expired() {
                    let e = item.ticket.deadline.timeout_error();
                    let _ = item.reply.send(error_bytes(item.request_id, &e));
                    sh.admission.note_timeout();
                    // retain_mut cannot move the ticket out; release by
                    // value via a swapped placeholder.
                    let ticket = std::mem::replace(&mut item.ticket, dead_ticket());
                    sh.admission.release(ticket);
                    false
                } else {
                    true
                }
            });
            !items.is_empty()
        });

        // Reap idle streaming sessions about once a second.
        if now.duration_since(last_reap) >= Duration::from_secs(1) {
            sh.streams.reap_idle();
            last_reap = now;
        }

        // Dispatch explicit batches first (no coalescing window).
        while !staged_batches.is_empty() {
            if sh.inflight.load(Ordering::Acquire) >= inflight_cap {
                break;
            }
            let (key, matrices, request_id, reply, ticket) = staged_batches.remove(0);
            sh.inflight.fetch_add(1, Ordering::AcqRel);
            if job_tx
                .send(GroupJob::Explicit { key, matrices, request_id, reply, ticket })
                .is_err()
            {
                sh.inflight.fetch_sub(1, Ordering::AcqRel);
                break;
            }
        }

        // Dispatch coalesced groups whose window has elapsed (or
        // immediately when draining — nothing more will join them).
        let ready: Vec<ShapeKey> = staged
            .iter()
            .filter(|(_, items)| {
                draining
                    || items
                        .first()
                        .is_some_and(|it| now.duration_since(it.enqueued) >= window)
            })
            .map(|(k, _)| *k)
            .collect();
        for key in ready {
            if sh.inflight.load(Ordering::Acquire) >= inflight_cap {
                break;
            }
            if let Some(items) = staged.remove(&key) {
                sh.inflight.fetch_add(1, Ordering::AcqRel);
                if job_tx.send(GroupJob::Coalesced { key, items }).is_err() {
                    sh.inflight.fetch_sub(1, Ordering::AcqRel);
                }
            }
        }

        // Drain complete: nothing staged, nothing inflight, no admitted
        // request anywhere (inline ops hold tickets too), and the work
        // channel was empty this tick.
        if (draining || disconnected)
            && staged.is_empty()
            && staged_batches.is_empty()
            && sh.inflight.load(Ordering::Acquire) == 0
            && sh.admission.queued() == 0
        {
            break;
        }
    }
    sh.stop.store(true, Ordering::Release);
    // Dropping job_tx ends the workers once their queues drain.
}

/// A placeholder ticket for `retain_mut` extraction (its slot is the
/// real ticket's, released immediately after the swap).
fn dead_ticket() -> Ticket {
    Ticket { deadline: super::admission::Deadline::in_ms(0) }
}

fn stage(
    staged: &mut HashMap<ShapeKey, Vec<OneItem>>,
    staged_batches: &mut Vec<(ShapeKey, Vec<Mat>, u64, Sender<Vec<u8>>, Ticket)>,
    w: Work,
) {
    match w {
        Work::One { key, item } => staged.entry(key).or_default().push(item),
        Work::Batch { key, matrices, request_id, reply, ticket } => {
            staged_batches.push((key, matrices, request_id, reply, ticket));
        }
    }
}

// ---------------------------------------------------------------------
// Workers
// ---------------------------------------------------------------------

fn worker_loop(sh: &Arc<Shared>, job_rx: &Arc<Mutex<Receiver<GroupJob>>>) {
    loop {
        // Holding the lock across recv serializes only the *dequeue*:
        // the waiting worker owns the lock, peers block on the mutex,
        // and computes run with the lock released.
        let job = {
            let rx = match job_rx.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            rx.recv()
        };
        let Ok(job) = job else { break };
        match job {
            GroupJob::Coalesced { key, items } => run_coalesced(sh, key, items),
            GroupJob::Explicit { key, matrices, request_id, reply, ticket } => {
                run_explicit(sh, key, matrices, request_id, reply, ticket)
            }
        }
        sh.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

fn run_coalesced(sh: &Shared, key: ShapeKey, items: Vec<OneItem>) {
    let mut session = match sh.pool.checkout(&key, sh.cfg.threads_per_job) {
        Ok(s) => s,
        Err(e) => {
            for item in items {
                let _ = item.reply.send(error_bytes(item.request_id, &e));
                sh.admission.release(item.ticket);
            }
            return;
        }
    };
    // Per-item validation before the batch: one bad matrix fails alone.
    let mut survivors: Vec<OneItem> = Vec::with_capacity(items.len());
    for item in items {
        let verdict = if item.ticket.deadline.expired() {
            sh.admission.note_timeout();
            Err(item.ticket.deadline.timeout_error())
        } else if sh.cfg.validate {
            item.matrix.validate_strict()
        } else {
            item.matrix.check_shape().map(|_| ())
        };
        match verdict {
            Ok(()) => survivors.push(item),
            Err(e) => {
                let _ = item.reply.send(error_bytes(item.request_id, &e));
                sh.admission.release(item.ticket);
            }
        }
    }
    if !survivors.is_empty() {
        let refs: Vec<&Mat> = survivors.iter().map(|it| &it.matrix).collect();
        let plan = session.plan_for(key.n);
        let (resolved, backend) = (plan.algorithm.name(), plan.backend.name());
        let t0 = Instant::now();
        match session.compute_batch_refs(&refs) {
            Ok(results) => {
                let per_item = t0.elapsed().as_secs_f64() / results.len().max(1) as f64;
                for (item, matrix) in survivors.into_iter().zip(results) {
                    let _ = item
                        .reply
                        .send(encode_response(item.request_id, &Response::Cohesion { matrix }));
                    sh.admission.release(item.ticket);
                    sh.metrics.record(JobMetrics {
                        n: key.n,
                        k: key.k,
                        algorithm: resolved.to_string(),
                        backend: backend.to_string(),
                        seconds: per_item,
                    });
                }
            }
            Err(e) => {
                for item in survivors {
                    let _ = item.reply.send(error_bytes(item.request_id, &e));
                    sh.admission.release(item.ticket);
                }
            }
        }
    }
    sh.pool.checkin(key, session);
}

fn run_explicit(
    sh: &Shared,
    key: ShapeKey,
    matrices: Vec<Mat>,
    request_id: u64,
    reply: Sender<Vec<u8>>,
    ticket: Ticket,
) {
    let mut session = match sh.pool.checkout(&key, sh.cfg.threads_per_job) {
        Ok(s) => s,
        Err(e) => {
            let _ = reply.send(error_bytes(request_id, &e));
            sh.admission.release(ticket);
            return;
        }
    };
    // A single response frame answers the whole batch, so any failing
    // item (validation or compute) fails the batch with a typed error.
    let outcome = (|| {
        if ticket.deadline.expired() {
            sh.admission.note_timeout();
            return Err(ticket.deadline.timeout_error());
        }
        if sh.cfg.validate {
            for m in &matrices {
                m.validate_strict()?;
            }
        }
        let refs: Vec<&Mat> = matrices.iter().collect();
        let t0 = Instant::now();
        let results = session.compute_batch_refs(&refs)?;
        let per_item = t0.elapsed().as_secs_f64() / results.len().max(1) as f64;
        let plan = session.plan_for(key.n);
        let (resolved, backend) = (plan.algorithm.name(), plan.backend.name());
        for m in &matrices {
            sh.metrics.record(JobMetrics {
                n: m.rows(),
                k: key.k,
                algorithm: resolved.to_string(),
                backend: backend.to_string(),
                seconds: per_item,
            });
        }
        Ok(results)
    })();
    match outcome {
        Ok(results) => {
            let _ = reply.send(encode_response(request_id, &Response::Batch { matrices: results }));
        }
        Err(e) => {
            let _ = reply.send(error_bytes(request_id, &e));
        }
    }
    sh.admission.release(ticket);
    sh.pool.checkin(key, session);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_defaults_are_sane() {
        let cfg = ServeConfig::default();
        assert!(cfg.queue_cap >= 1);
        assert!(cfg.max_frame >= 1 << 20);
        assert!(cfg.validate);
    }

    #[test]
    fn start_and_graceful_shutdown_via_handle() {
        let handle = Server::start(ServeConfig {
            addr: "127.0.0.1:0".into(),
            ..ServeConfig::default()
        })
        .unwrap();
        assert!(handle.addr().port() != 0);
        assert!(!handle.is_draining());
        handle.shutdown();
        assert!(handle.is_draining());
        let scrape = handle.join();
        assert!(scrape.contains("paldx_serve_draining 1"), "{scrape}");
        assert!(scrape.contains("paldx_jobs_total"), "{scrape}");
        assert!(scrape.contains("paldx_simd_available"), "{scrape}");
        // Identity gauges for fleet aggregation (DESIGN.md §14).
        assert!(scrape.contains("paldx_up 1"), "{scrape}");
        assert!(scrape.contains("paldx_server_start_ms "), "{scrape}");
        assert!(scrape.contains("paldx_server_workers "), "{scrape}");
        assert!(scrape.contains("paldx_server_threads_per_job 1"), "{scrape}");
    }

    #[test]
    fn signal_flag_roundtrip() {
        install_signal_handlers();
        assert!(!shutdown_requested() || SIGNAL_SHUTDOWN.load(Ordering::Acquire));
    }
}
