//! Warm-pool scheduler for `pald-serve`: shape-keyed [`Session`] reuse
//! with LRU eviction under a memory cap (DESIGN.md §12).
//!
//! A [`Session`] amortizes planning and workspace allocation across
//! computes of the same shape — exactly the steady-state the serving
//! layer lives in.  The pool keys warm sessions by
//! [`ShapeKey`]` = (n, k, algorithm, tie)`; the dispatcher coalesces
//! same-key one-shots arriving within a batch window into a single
//! `compute_batch_refs` call on one checked-out session, which is
//! bit-identical to serving them one at a time (the batch path maps
//! sequential [`Session::compute`] over the inputs — proved end-to-end
//! by `tests/serve.rs`).
//!
//! Memory is bounded: each warm session is charged its
//! `workspace_bytes()` plus one cohesion matrix (`n² × 4` — the
//! `cohesion_bytes` a checkin produces), and when the pool's total
//! crosses the cap, least-recently-used sessions are dropped until it
//! fits.  A session larger than the whole cap is simply never retained.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::pald::error::PaldError;
use crate::pald::{Algorithm, CohesionSemantics, PaldConfig, Session, TieMode};

use super::proto::WireConfig;

/// Identity of a warm session: two requests with the same key are
/// served bit-identically by the same session, so they may share one.
/// Algorithm and tie ride as `&'static str` registry names (the enums
/// interned them; neither derives `Hash`).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct ShapeKey {
    /// Problem size.
    pub n: usize,
    /// Truncated-neighborhood size (`0` = dense).
    pub k: usize,
    /// Registry algorithm name (possibly `"auto"`; the session's
    /// planner resolves it per compute, deterministically for fixed
    /// `(n, k)`).
    pub algorithm: &'static str,
    /// Tie-mode name.
    pub tie: &'static str,
    /// Cohesion-semantics name (DESIGN.md §15): semantics change the
    /// numbers, so they shape the session identity like the tie mode.
    pub semantics: &'static str,
}

impl ShapeKey {
    /// Key for a request: `n` from the input matrix, the rest from its
    /// wire options.  Unknown algorithm names are a typed error (the
    /// request is rejected before any session is built).
    pub fn for_request(cfg: &WireConfig, n: usize) -> Result<ShapeKey, PaldError> {
        let algorithm = Algorithm::from_name(&cfg.algorithm)?;
        Ok(ShapeKey {
            n,
            k: cfg.k as usize,
            algorithm: algorithm.name(),
            tie: cfg.tie.name(),
            semantics: cfg.semantics.name(),
        })
    }
}

/// Build the [`PaldConfig`] a key's sessions run under.  `threads` is
/// server policy (`threads_per_job`), not client-controlled.
pub fn config_for(key: &ShapeKey, threads: usize) -> Result<PaldConfig, PaldError> {
    Ok(PaldConfig {
        algorithm: Algorithm::from_name(key.algorithm)?,
        tie_mode: TieMode::parse(key.tie)?,
        semantics: CohesionSemantics::parse(key.semantics)?,
        k: key.k,
        threads,
        ..PaldConfig::default()
    })
}

struct Warm {
    session: Session,
    bytes: usize,
    last_used: u64,
}

struct Inner {
    /// Warm sessions by shape; more than one per key can exist when
    /// same-shape requests overlap.
    warm: HashMap<ShapeKey, Vec<Warm>>,
    total_bytes: usize,
    clock: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Shape-keyed warm-session pool with LRU eviction under `mem_cap`
/// bytes.  Checkout/checkin are short critical sections; computes run
/// on checked-out sessions outside the lock.
pub struct WarmPool {
    inner: Mutex<Inner>,
    mem_cap: usize,
}

impl WarmPool {
    /// Pool retaining at most `mem_cap` bytes of warm state.
    pub fn new(mem_cap: usize) -> WarmPool {
        WarmPool {
            inner: Mutex::new(Inner {
                warm: HashMap::new(),
                total_bytes: 0,
                clock: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
            mem_cap,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // A panic inside these short critical sections is a bug, but a
        // poisoned pool must not take the whole server down with it.
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Check out a session for `key`, reusing a warm one when present
    /// (its planning and workspaces are already shaped) or building a
    /// fresh one.  The caller runs the compute, then returns the
    /// session via [`WarmPool::checkin`].
    pub fn checkout(&self, key: &ShapeKey, threads: usize) -> Result<Session, PaldError> {
        {
            let mut inner = self.lock();
            if let Some(list) = inner.warm.get_mut(key) {
                if let Some(w) = list.pop() {
                    if list.is_empty() {
                        inner.warm.remove(key);
                    }
                    inner.total_bytes -= w.bytes;
                    inner.hits += 1;
                    return Ok(w.session);
                }
            }
            inner.misses += 1;
        }
        Session::new(config_for(key, threads)?)
    }

    /// Return a session to the pool.  It is charged its workspace bytes
    /// plus one `n² × 4` cohesion matrix, then LRU eviction runs until
    /// the pool fits its cap again.
    pub fn checkin(&self, key: ShapeKey, session: Session) {
        let bytes = session.workspace_bytes() + cohesion_bytes(key.n);
        let mut inner = self.lock();
        if bytes > self.mem_cap {
            // Larger than the whole budget: never retained.
            inner.evictions += 1;
            return;
        }
        inner.clock += 1;
        let last_used = inner.clock;
        inner.warm.entry(key).or_default().push(Warm { session, bytes, last_used });
        inner.total_bytes += bytes;
        while inner.total_bytes > self.mem_cap {
            // Find the least-recently-used warm session across shapes.
            let lru = inner
                .warm
                .iter()
                .filter_map(|(k, list)| {
                    list.iter().map(|w| (w.last_used, *k)).min_by_key(|(t, _)| *t)
                })
                .min_by_key(|(t, _)| *t);
            let Some((stamp, k)) = lru else { break };
            if let Some(list) = inner.warm.get_mut(&k) {
                if let Some(at) = list.iter().position(|w| w.last_used == stamp) {
                    let w = list.remove(at);
                    inner.total_bytes -= w.bytes;
                    inner.evictions += 1;
                }
                if list.is_empty() {
                    inner.warm.remove(&k);
                }
            }
        }
    }

    /// Bytes of warm state currently retained.
    pub fn bytes(&self) -> usize {
        self.lock().total_bytes
    }

    /// Warm sessions currently retained.
    pub fn len(&self) -> usize {
        self.lock().warm.values().map(Vec::len).sum()
    }

    /// Is the pool empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counters for the scrape endpoint: `(hits, misses, evictions)`.
    pub fn counters(&self) -> (u64, u64, u64) {
        let inner = self.lock();
        (inner.hits, inner.misses, inner.evictions)
    }
}

/// Bytes of one dense `n × n` cohesion matrix — the result each warm
/// session's next compute will materialize.
pub fn cohesion_bytes(n: usize) -> usize {
    n * n * std::mem::size_of::<f32>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::distmat;

    fn key(n: usize) -> ShapeKey {
        ShapeKey { n, k: 0, algorithm: "auto", tie: "strict", semantics: "classic" }
    }

    #[test]
    fn shape_key_resolves_wire_options() {
        let cfg = WireConfig {
            algorithm: "opt-pairwise".into(),
            tie: TieMode::Split,
            semantics: CohesionSemantics::RankBased,
            k: 8,
            deadline_ms: 0,
        };
        let k = ShapeKey::for_request(&cfg, 64).unwrap();
        assert_eq!(
            k,
            ShapeKey {
                n: 64,
                k: 8,
                algorithm: "opt-pairwise",
                tie: "split",
                semantics: "rank",
            }
        );
        let bad = WireConfig { algorithm: "no-such-kernel".into(), ..WireConfig::default() };
        assert!(ShapeKey::for_request(&bad, 64).is_err());
    }

    #[test]
    fn checkout_reuses_warm_sessions() {
        let pool = WarmPool::new(64 << 20);
        let k = key(24);
        let d = distmat::random_tie_free(24, 3);
        let mut s = pool.checkout(&k, 1).unwrap();
        let c1 = s.compute(&d).unwrap();
        pool.checkin(k, s);
        assert_eq!(pool.len(), 1);
        let mut s2 = pool.checkout(&k, 1).unwrap();
        let c2 = s2.compute(&d).unwrap();
        assert_eq!(c1, c2, "warm session must be bit-identical");
        pool.checkin(k, s2);
        let (hits, misses, _) = pool.counters();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn lru_eviction_respects_memory_cap() {
        // Workspaces are sized by the first compute, so measure a warmed
        // n=24 session and cap the pool at exactly that footprint.
        let k16 = key(16);
        let k24 = key(24);
        let mut s24 = Session::new(config_for(&k24, 1).unwrap()).unwrap();
        s24.compute(&distmat::random_tie_free(24, 3)).unwrap();
        let one = s24.workspace_bytes() + cohesion_bytes(24);
        let pool = WarmPool::new(one);
        let mut s16 = Session::new(config_for(&k16, 1).unwrap()).unwrap();
        s16.compute(&distmat::random_tie_free(16, 3)).unwrap();
        pool.checkin(k16, s16);
        assert_eq!(pool.len(), 1);
        // The bigger checkin pushes the total over cap; the older (LRU)
        // n=16 session goes first.
        pool.checkin(k24, s24);
        assert!(pool.bytes() <= one, "cap respected: {} > {one}", pool.bytes());
        let (_, _, evictions) = pool.counters();
        assert!(evictions >= 1);
        // The survivor is the newer key.
        let (hits_before, _, _) = pool.counters();
        let _s = pool.checkout(&k24, 1).unwrap();
        let (hits_after, _, _) = pool.counters();
        assert_eq!(hits_after, hits_before + 1, "n=24 stayed warm");
    }

    #[test]
    fn oversized_sessions_are_never_retained() {
        let pool = WarmPool::new(8); // 8 bytes: nothing fits
        let k = key(16);
        let s = Session::new(config_for(&k, 1).unwrap()).unwrap();
        pool.checkin(k, s);
        assert!(pool.is_empty());
        assert_eq!(pool.bytes(), 0);
    }
}
