//! Load generator for `pald-serve`: closed-loop and open-loop request
//! streams over a mixed-shape workload, with per-mix latency quantiles
//! (p50/p95/p99) and throughput — the measurement half of DESIGN.md
//! §12, published as `BENCH_serve.json` by `paldx loadgen`.
//!
//! * **Closed loop** (`rate == 0`): each of `concurrency` connections
//!   issues requests back-to-back — measures the server's saturated
//!   throughput and its latency under self-limiting load.
//! * **Open loop** (`rate > 0`): arrivals are scheduled on a global
//!   clock at `rate` requests/second and handed to whichever connection
//!   is free — measures latency at a fixed offered load, where queueing
//!   (and load shedding) actually shows.  Retriable rejects
//!   ([`PaldError::is_retriable`]) are counted as sheds, not failures:
//!   an overloaded server refusing work politely is the designed
//!   behavior, while any protocol error fails the run.
//!
//! The target may equally be a `paldx router` front-tier — the wire
//! protocol is identical.  With `retries > 0` each connection drives a
//! [`ReconnectClient`] and requests that succeeded only after a retry
//! are counted (`retried_ok`) separately from sheds; with
//! `report_distribution` the target's scrape is diffed across the run
//! to report how the router spread requests over its backends
//! (`paldx loadgen --report-distribution` → `BENCH_router.json`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::data::distmat;
use crate::io::Json;
use crate::pald::error::PaldError;

use super::client::{ReconnectClient, RetryPolicy, ServeClient};
use super::proto::WireConfig;

/// One shape in the workload mix.
#[derive(Clone, Debug)]
pub struct MixSpec {
    /// Label in reports.
    pub name: String,
    /// Problem size.
    pub n: usize,
    /// Truncated-neighborhood size (`0` = dense).
    pub k: u32,
    /// Relative weight in the mix (picked proportionally).
    pub weight: u32,
}

/// Load-generation options.
#[derive(Clone, Debug)]
pub struct LoadgenOpts {
    /// Server address.
    pub addr: String,
    /// How long to generate load.
    pub duration: Duration,
    /// Concurrent connections.
    pub concurrency: usize,
    /// Offered load in requests/second (`0` = closed loop).
    pub rate: f64,
    /// The shape mix (must be non-empty).
    pub mixes: Vec<MixSpec>,
    /// Algorithm requested (`"auto"` for the planner).
    pub algorithm: String,
    /// Per-request deadline in ms (`0` = server default).
    pub deadline_ms: u32,
    /// RNG seed for mix picking and input generation.
    pub seed: u64,
    /// Client-side retries per request (`0` = none).  When set, each
    /// connection is a [`ReconnectClient`] retrying sheds and transport
    /// failures under seeded-jitter backoff.
    pub retries: u32,
    /// Diff the target's scrape across the run and report per-backend
    /// request distribution (meaningful against a `paldx router`
    /// target; empty against a plain server).
    pub report_distribution: bool,
}

impl Default for LoadgenOpts {
    fn default() -> Self {
        LoadgenOpts {
            addr: "127.0.0.1:7465".into(),
            duration: Duration::from_secs(2),
            concurrency: 4,
            rate: 0.0,
            mixes: default_mixes(),
            algorithm: "auto".into(),
            deadline_ms: 0,
            seed: 42,
            retries: 0,
            report_distribution: false,
        }
    }
}

/// The default two-shape mix: small dense one-shots (coalescing fodder)
/// and a larger truncated shape (the sparse serving path).
pub fn default_mixes() -> Vec<MixSpec> {
    vec![
        MixSpec { name: "dense-small".into(), n: 64, k: 0, weight: 3 },
        MixSpec { name: "sparse-mid".into(), n: 192, k: 12, weight: 1 },
    ]
}

/// Latency quantiles over one mix (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct Quantiles {
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Worst observed.
    pub max: f64,
}

/// Per-mix results.
#[derive(Clone, Debug)]
pub struct MixReport {
    /// Mix label.
    pub name: String,
    /// Problem size.
    pub n: usize,
    /// Truncated-neighborhood size.
    pub k: u32,
    /// Requests sent.
    pub sent: u64,
    /// Successful responses.
    pub ok: u64,
    /// Of `ok`, responses that needed at least one client-side retry —
    /// requests the fleet initially shed (or dropped) but ultimately
    /// answered.  Counted separately from `shed`, which is requests
    /// that *stayed* rejected.
    pub retried_ok: u64,
    /// Retriable rejects (overload / draining sheds).
    pub shed: u64,
    /// Deadline timeouts.
    pub timeouts: u64,
    /// Non-retriable failures.
    pub errors: u64,
    /// Latency quantiles over successful requests.
    pub latency: Quantiles,
}

/// Whole-run results.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    /// `"closed-loop"` or `"open-loop"`.
    pub mode: &'static str,
    /// Wall-clock seconds the run took.
    pub elapsed_s: f64,
    /// Successful responses/second over the run.
    pub rps: f64,
    /// Per-mix breakdowns.
    pub mixes: Vec<MixReport>,
    /// Wire-protocol errors (any is a failed run).
    pub protocol_errors: u64,
    /// Per-backend request distribution over the run (router targets):
    /// `(backend_addr, requests_dispatched)`.  Empty when the target is
    /// a plain server or distribution reporting was off.
    pub backends: Vec<(String, u64)>,
}

impl LoadgenReport {
    /// Totals across mixes: `(sent, ok, shed, timeouts, errors)`.
    pub fn totals(&self) -> (u64, u64, u64, u64, u64) {
        self.mixes.iter().fold((0, 0, 0, 0, 0), |acc, m| {
            (acc.0 + m.sent, acc.1 + m.ok, acc.2 + m.shed, acc.3 + m.timeouts, acc.4 + m.errors)
        })
    }

    /// Requests that succeeded only after at least one retry, across
    /// mixes.
    pub fn retried_ok_total(&self) -> u64 {
        self.mixes.iter().map(|m| m.retried_ok).sum()
    }

    /// Render as the `BENCH_serve.json` / `BENCH_router.json` payload.
    pub fn to_json(&self) -> Json {
        let (sent, ok, shed, timeouts, errors) = self.totals();
        let experiment = if self.backends.is_empty() { "serve" } else { "router" };
        Json::Obj(vec![
            ("experiment".into(), Json::Str(experiment.into())),
            ("mode".into(), Json::Str(self.mode.into())),
            ("elapsed_s".into(), Json::Num(self.elapsed_s)),
            ("rps".into(), Json::Num(self.rps)),
            ("sent".into(), Json::Num(sent as f64)),
            ("ok".into(), Json::Num(ok as f64)),
            ("retried_ok".into(), Json::Num(self.retried_ok_total() as f64)),
            ("shed".into(), Json::Num(shed as f64)),
            ("timeouts".into(), Json::Num(timeouts as f64)),
            ("errors".into(), Json::Num(errors as f64)),
            ("protocol_errors".into(), Json::Num(self.protocol_errors as f64)),
            (
                "backends".into(),
                Json::Arr(
                    self.backends
                        .iter()
                        .map(|(addr, n)| {
                            Json::Obj(vec![
                                ("addr".into(), Json::Str(addr.clone())),
                                ("forwarded".into(), Json::Num(*n as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "mixes".into(),
                Json::Arr(
                    self.mixes
                        .iter()
                        .map(|m| {
                            Json::Obj(vec![
                                ("name".into(), Json::Str(m.name.clone())),
                                ("n".into(), Json::Num(m.n as f64)),
                                ("k".into(), Json::Num(m.k as f64)),
                                ("sent".into(), Json::Num(m.sent as f64)),
                                ("ok".into(), Json::Num(m.ok as f64)),
                                ("retried_ok".into(), Json::Num(m.retried_ok as f64)),
                                ("shed".into(), Json::Num(m.shed as f64)),
                                ("timeouts".into(), Json::Num(m.timeouts as f64)),
                                ("errors".into(), Json::Num(m.errors as f64)),
                                ("p50_s".into(), Json::Num(m.latency.p50)),
                                ("p95_s".into(), Json::Num(m.latency.p95)),
                                ("p99_s".into(), Json::Num(m.latency.p99)),
                                ("max_s".into(), Json::Num(m.latency.max)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

fn xorshift(s: &mut u64) -> u64 {
    *s ^= *s << 13;
    *s ^= *s >> 7;
    *s ^= *s << 17;
    *s
}

/// Quantile over sorted latencies: the ceil-rank convention.
fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Compute quantiles from an unsorted latency sample.
pub fn quantiles(mut latencies: Vec<f64>) -> Quantiles {
    latencies.sort_by(|a, b| a.total_cmp(b));
    Quantiles {
        p50: quantile(&latencies, 0.50),
        p95: quantile(&latencies, 0.95),
        p99: quantile(&latencies, 0.99),
        max: latencies.last().copied().unwrap_or(0.0),
    }
}

enum Outcome {
    /// Latency (seconds) and client-side retries the request needed.
    Ok(f64, u32),
    Shed,
    Timeout,
    Error,
    Protocol,
}

/// Fetch the target's per-backend dispatch counters
/// (`paldx_router_backend_forwarded_total{backend="…"}`) via an in-band
/// `STATS` frame.  Empty against a plain `pald-serve` target (it has no
/// such series) or when the scrape cannot be fetched.
fn scrape_distribution(addr: &str) -> Vec<(String, u64)> {
    const SERIES: &str = "paldx_router_backend_forwarded_total{backend=\"";
    let Ok(mut client) = ServeClient::connect(addr) else { return Vec::new() };
    let Ok(text) = client.stats() else { return Vec::new() };
    let mut out = Vec::new();
    for line in text.lines() {
        let Some(rest) = line.strip_prefix(SERIES) else { continue };
        let Some((name, value)) = rest.split_once("\"}") else { continue };
        if let Ok(v) = value.trim().parse::<u64>() {
            out.push((name.to_string(), v));
        }
    }
    out
}

/// Per-backend deltas across the run (`after - before`; backends that
/// appeared mid-run count from zero).
fn distribution_delta(
    before: &[(String, u64)],
    after: Vec<(String, u64)>,
) -> Vec<(String, u64)> {
    after
        .into_iter()
        .map(|(name, v)| {
            let base =
                before.iter().find(|(n, _)| *n == name).map(|(_, b)| *b).unwrap_or(0);
            (name, v.saturating_sub(base))
        })
        .collect()
}

/// Run the load generator against a live server.
pub fn run(opts: &LoadgenOpts) -> Result<LoadgenReport, PaldError> {
    if opts.mixes.is_empty() {
        return Err(PaldError::protocol("loadgen needs at least one mix"));
    }
    if opts.concurrency == 0 {
        return Err(PaldError::protocol("loadgen needs at least one connection"));
    }
    // One input matrix per mix, generated once and shared read-only.
    let inputs: Vec<crate::core::Mat> = opts
        .mixes
        .iter()
        .enumerate()
        .map(|(i, m)| distmat::random_tie_free(m.n, opts.seed.wrapping_add(i as u64)))
        .collect();
    let weight_total: u64 = opts.mixes.iter().map(|m| m.weight.max(1) as u64).sum();

    let distribution_before =
        if opts.report_distribution { scrape_distribution(&opts.addr) } else { Vec::new() };
    let start = Instant::now();
    let deadline = start + opts.duration;
    // Open-loop arrival schedule: request i departs at start + i/rate.
    let arrivals = AtomicU64::new(0);
    let open_loop = opts.rate > 0.0;

    let worker = |widx: usize| -> Vec<(usize, Outcome)> {
        let mut out = Vec::new();
        let mut rng = (opts.seed ^ 0x9e3779b97f4a7c15u64.wrapping_mul(widx as u64 + 1)) | 1;
        // With a retry budget the connection is a ReconnectClient:
        // sheds and transport failures retry with backoff, and dials
        // are lazy so a not-yet-listening target is a retried failure
        // rather than an immediate protocol error.
        let mut retry_client = if opts.retries > 0 {
            Some(ReconnectClient::new(
                &opts.addr,
                RetryPolicy {
                    max_retries: opts.retries,
                    base_ms: 5,
                    cap_ms: 250,
                    seed: opts.seed ^ (widx as u64) << 17,
                },
            ))
        } else {
            None
        };
        let mut client = match retry_client {
            Some(_) => None,
            None => match ServeClient::connect(&opts.addr) {
                Ok(c) => Some(c),
                Err(_) => {
                    out.push((0, Outcome::Protocol));
                    return out;
                }
            },
        };
        loop {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            if open_loop {
                // Claim the next scheduled arrival; sleep until it.
                let i = arrivals.fetch_add(1, Ordering::Relaxed);
                let at = start + Duration::from_secs_f64(i as f64 / opts.rate);
                if at >= deadline {
                    break;
                }
                if at > now {
                    std::thread::sleep(at - now);
                }
            }
            // Weighted mix pick.
            let mut roll = xorshift(&mut rng) % weight_total;
            let mut mix_idx = 0;
            for (i, m) in opts.mixes.iter().enumerate() {
                let w = m.weight.max(1) as u64;
                if roll < w {
                    mix_idx = i;
                    break;
                }
                roll -= w;
            }
            let mix = &opts.mixes[mix_idx];
            let cfg = WireConfig {
                algorithm: opts.algorithm.clone(),
                tie: crate::pald::TieMode::Strict,
                semantics: crate::pald::CohesionSemantics::Classic,
                k: mix.k,
                deadline_ms: opts.deadline_ms,
            };
            let t0 = Instant::now();
            let outcome = if let Some(rc) = retry_client.as_mut() {
                match rc.compute_with_retry(&cfg, &inputs[mix_idx]) {
                    Ok(c) => {
                        debug_assert_eq!(c.rows(), mix.n);
                        Outcome::Ok(t0.elapsed().as_secs_f64(), rc.last_call_retries())
                    }
                    Err(PaldError::Timeout { .. }) => Outcome::Timeout,
                    Err(e) if e.is_retriable() => Outcome::Shed,
                    // RetriesExhausted (budget spent on sheds or dead
                    // connections) and other hard failures; the client
                    // re-dials lazily, so the loop continues.
                    Err(_) => Outcome::Error,
                }
            } else {
                let c = client.as_mut().expect("plain client when retries == 0");
                match c.compute(&cfg, &inputs[mix_idx]) {
                    Ok(c) => {
                        debug_assert_eq!(c.rows(), mix.n);
                        Outcome::Ok(t0.elapsed().as_secs_f64(), 0)
                    }
                    Err(e) if e.is_retriable() => Outcome::Shed,
                    Err(PaldError::Timeout { .. }) => Outcome::Timeout,
                    Err(PaldError::Protocol { .. }) => {
                        // Protocol errors close the connection
                        // server-side; reconnect before the next
                        // request.
                        match ServeClient::connect(&opts.addr) {
                            Ok(fresh) => client = Some(fresh),
                            Err(_) => {
                                out.push((mix_idx, Outcome::Protocol));
                                break;
                            }
                        }
                        Outcome::Protocol
                    }
                    Err(_) => Outcome::Error,
                }
            };
            out.push((mix_idx, outcome));
        }
        out
    };

    let worker = &worker;
    let all: Vec<(usize, Outcome)> = std::thread::scope(|scope| {
        let handles: Vec<_> =
            (0..opts.concurrency).map(|w| scope.spawn(move || worker(w))).collect();
        handles.into_iter().flat_map(|h| h.join().unwrap_or_default()).collect()
    });
    let elapsed_s = start.elapsed().as_secs_f64();

    let mut protocol_errors = 0u64;
    #[derive(Clone, Default)]
    struct Acc {
        sent: u64,
        ok: u64,
        retried_ok: u64,
        shed: u64,
        timeouts: u64,
        errors: u64,
        lats: Vec<f64>,
    }
    let mut per_mix: Vec<Acc> = vec![Acc::default(); opts.mixes.len()];
    for (mix_idx, outcome) in all {
        let slot = &mut per_mix[mix_idx];
        slot.sent += 1;
        match outcome {
            Outcome::Ok(lat, retries) => {
                slot.ok += 1;
                if retries > 0 {
                    slot.retried_ok += 1;
                }
                slot.lats.push(lat);
            }
            Outcome::Shed => slot.shed += 1,
            Outcome::Timeout => slot.timeouts += 1,
            Outcome::Error => slot.errors += 1,
            Outcome::Protocol => {
                slot.errors += 1;
                protocol_errors += 1;
            }
        }
    }
    let mixes: Vec<MixReport> = opts
        .mixes
        .iter()
        .zip(per_mix)
        .map(|(m, acc)| MixReport {
            name: m.name.clone(),
            n: m.n,
            k: m.k,
            sent: acc.sent,
            ok: acc.ok,
            retried_ok: acc.retried_ok,
            shed: acc.shed,
            timeouts: acc.timeouts,
            errors: acc.errors,
            latency: quantiles(acc.lats),
        })
        .collect();
    let backends = if opts.report_distribution {
        distribution_delta(&distribution_before, scrape_distribution(&opts.addr))
    } else {
        Vec::new()
    };
    let ok_total: u64 = mixes.iter().map(|m| m.ok).sum();
    Ok(LoadgenReport {
        mode: if open_loop { "open-loop" } else { "closed-loop" },
        elapsed_s,
        rps: ok_total as f64 / elapsed_s.max(1e-9),
        mixes,
        protocol_errors,
        backends,
    })
}

/// Parse a `--mix` spec: comma-separated `name:n:k:weight` entries,
/// e.g. `dense:64:0:3,sparse:256:16:1`.
pub fn parse_mixes(spec: &str) -> Result<Vec<MixSpec>, PaldError> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.is_empty()) {
        let fields: Vec<&str> = part.split(':').collect();
        if fields.len() != 4 {
            return Err(PaldError::protocol(format!(
                "bad mix entry '{part}' (want name:n:k:weight)"
            )));
        }
        let parse = |s: &str, what: &str| -> Result<u64, PaldError> {
            s.parse::<u64>()
                .map_err(|_| PaldError::protocol(format!("bad mix {what} '{s}' in '{part}'")))
        };
        out.push(MixSpec {
            name: fields[0].to_string(),
            n: parse(fields[1], "n")? as usize,
            k: parse(fields[2], "k")? as u32,
            weight: parse(fields[3], "weight")? as u32,
        });
    }
    if out.is_empty() {
        return Err(PaldError::protocol("empty --mix spec"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_follow_ceil_rank_convention() {
        let lats: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        let q = quantiles(lats);
        assert_eq!(q.p50, 50.0);
        assert_eq!(q.p95, 95.0);
        assert_eq!(q.p99, 99.0);
        assert_eq!(q.max, 100.0);
        let one = quantiles(vec![7.0]);
        assert_eq!((one.p50, one.p99, one.max), (7.0, 7.0, 7.0));
        let none = quantiles(vec![]);
        assert_eq!(none.max, 0.0);
    }

    #[test]
    fn mix_spec_parses_and_rejects() {
        let mixes = parse_mixes("dense:64:0:3,sparse:256:16:1").unwrap();
        assert_eq!(mixes.len(), 2);
        assert_eq!(mixes[0].name, "dense");
        assert_eq!((mixes[1].n, mixes[1].k, mixes[1].weight), (256, 16, 1));
        assert!(parse_mixes("").is_err());
        assert!(parse_mixes("only:three:fields").is_err());
        assert!(parse_mixes("bad:n?:0:1").is_err());
    }

    #[test]
    fn report_json_has_the_quantile_fields() {
        let mut report = LoadgenReport {
            mode: "closed-loop",
            elapsed_s: 1.5,
            rps: 100.0,
            mixes: vec![MixReport {
                name: "dense-small".into(),
                n: 64,
                k: 0,
                sent: 150,
                ok: 148,
                retried_ok: 3,
                shed: 2,
                timeouts: 0,
                errors: 0,
                latency: Quantiles { p50: 0.01, p95: 0.02, p99: 0.03, max: 0.05 },
            }],
            protocol_errors: 0,
            backends: Vec::new(),
        };
        let text = report.to_json().render();
        let keys = ["\"p50_s\"", "\"p95_s\"", "\"p99_s\"", "\"rps\"", "\"protocol_errors\""];
        for key in keys.iter().chain(&["\"retried_ok\""]) {
            assert!(text.contains(key), "{key} missing from {text}");
        }
        assert_eq!(report.totals().0, 150);
        assert_eq!(report.retried_ok_total(), 3);
        // Without a distribution the payload is the serve experiment;
        // with one it becomes the router experiment.
        assert!(text.contains("\"experiment\":\"serve\""), "{text}");
        report.backends = vec![("127.0.0.1:7465".into(), 120), ("127.0.0.1:7466".into(), 30)];
        let text = report.to_json().render();
        assert!(text.contains("\"experiment\":\"router\""), "{text}");
        assert!(text.contains("127.0.0.1:7466"), "{text}");
    }

    #[test]
    fn distribution_parses_router_series_and_diffs() {
        let scrape = "\
# fleet\n\
paldx_backend_up 2\n\
paldx_router_backend_forwarded_total{backend=\"127.0.0.1:7465\"} 40\n\
paldx_router_backend_forwarded_total{backend=\"127.0.0.1:7466\"} 10\n\
paldx_up{backend=\"127.0.0.1:7465\"} 1\n";
        let parse = |text: &str| -> Vec<(String, u64)> {
            const SERIES: &str = "paldx_router_backend_forwarded_total{backend=\"";
            text.lines()
                .filter_map(|l| l.strip_prefix(SERIES))
                .filter_map(|rest| rest.split_once("\"}"))
                .filter_map(|(name, v)| {
                    v.trim().parse::<u64>().ok().map(|v| (name.to_string(), v))
                })
                .collect()
        };
        let before = parse(scrape);
        assert_eq!(before.len(), 2);
        assert_eq!(before[0], ("127.0.0.1:7465".to_string(), 40));
        let after = vec![
            ("127.0.0.1:7465".to_string(), 100),
            ("127.0.0.1:7466".to_string(), 25),
            ("127.0.0.1:7467".to_string(), 5),
        ];
        let delta = distribution_delta(&before, after);
        assert_eq!(
            delta,
            vec![
                ("127.0.0.1:7465".to_string(), 60),
                ("127.0.0.1:7466".to_string(), 15),
                // A backend that appeared mid-run counts from zero.
                ("127.0.0.1:7467".to_string(), 5),
            ]
        );
    }
}
