//! `pald-serve`: an async serving layer for PaLD cohesion over a
//! length-prefixed TCP wire protocol (DESIGN.md §12).
//!
//! The serving layer turns the library's amortized machinery —
//! [`Session`](crate::pald::Session) plan/workspace reuse and
//! [`IncrementalPald`](crate::pald::IncrementalPald) online updates —
//! into a long-running process with explicit overload behavior:
//!
//! * [`proto`] — the framed wire protocol: versioned header, typed
//!   request/response frames, and total decoding (malformed input is a
//!   typed error, never a panic).
//! * [`admission`] — bounded-queue admission control: per-request
//!   deadlines, retriable load-shedding when the queue is full, and a
//!   drain mode for graceful shutdown.
//! * [`pool`] — the warm-pool scheduler: sessions keyed by
//!   `(n, k, algorithm, tie)` shape, reused across requests, LRU-evicted
//!   under a memory cap.  Same-shape one-shots arriving within the batch
//!   window are coalesced into a single batched compute — bit-identical
//!   to serving them one at a time.
//! * [`stream`] — streaming sessions: wire-addressable incremental
//!   engines with insert/remove/query and idle reaping.
//! * [`server`] — the server itself: acceptor, per-connection
//!   reader/writer threads, the coalescing dispatcher, a worker pool,
//!   signal-driven graceful drain, and a plaintext metrics scrape
//!   (in-band `STATS` frame or `GET /metrics` on the same port).
//! * [`client`] — blocking clients: [`ServeClient`] (one connection,
//!   used by `paldx loadgen` and the end-to-end tests) and
//!   [`ReconnectClient`] (re-dials with capped, seeded-jitter
//!   exponential backoff driven by the retriable error codes; the
//!   router's backend pool is built from these).
//! * [`loadgen`] — closed-loop and open-loop load generation with
//!   per-mix latency quantiles, publishing `BENCH_serve.json`.
//!
//! Everything is std-only: threads and channels, no async runtime.

pub mod admission;
pub mod client;
pub mod loadgen;
pub mod pool;
pub mod proto;
pub mod server;
pub mod stream;

pub use admission::{Admission, Deadline, Ticket};
pub use client::{ReconnectClient, RetryPolicy, ServeClient};
pub use loadgen::{LoadgenOpts, LoadgenReport, MixSpec};
pub use pool::{ShapeKey, WarmPool};
pub use proto::{ErrorCode, Request, Response, WireConfig};
pub use server::{
    install_signal_handlers, shutdown_requested, ServeConfig, Server, ServerHandle,
};
pub use stream::StreamSessions;
