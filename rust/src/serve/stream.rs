//! Streaming sessions for `pald-serve`: wire-addressable
//! [`IncrementalPald`] engines (DESIGN.md §12).
//!
//! A `SESSION_OPEN` frame seeds an online engine; subsequent
//! `SESSION_INSERT` / `SESSION_REMOVE` / `SESSION_QUERY` frames address
//! it by id, paying the engine's O(n·k) (truncated) or O(n²) (dense)
//! per-update cost instead of recomputing from scratch — the Online
//! PaLD pattern served over TCP.  Engines run under
//! [`ReanchorPolicy::EveryN`] (server policy) so long-lived sessions
//! periodically re-anchor accumulated floating-point drift.
//!
//! The registry holds each engine behind its own `Mutex` so a slow
//! query on one session never blocks updates to another; the map lock
//! is only ever held for id lookup.  Sessions idle past the server's
//! timeout are reaped by the dispatcher tick.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::core::Mat;
use crate::pald::error::PaldError;
use crate::pald::{
    IncrementalPald, Neighborhood, PaldBuilder, ReanchorPolicy, Threads, Validation,
};

use super::proto::{ErrorCode, WireConfig};

/// A failed streaming-session operation, carrying enough to build the
/// wire error frame.
#[derive(Debug)]
pub enum StreamError {
    /// No session with this id (never opened, closed, or idle-reaped).
    NoSuchSession(u64),
    /// The engine rejected the operation.
    Pald(PaldError),
}

impl StreamError {
    /// Wire representation: `(code, info, detail)`.
    pub fn to_wire(&self) -> (ErrorCode, u64, String) {
        match self {
            StreamError::NoSuchSession(id) => {
                (ErrorCode::NoSuchSession, *id, format!("no such session {id}"))
            }
            StreamError::Pald(e) => super::proto::pald_error_to_wire(e),
        }
    }
}

impl From<PaldError> for StreamError {
    fn from(e: PaldError) -> StreamError {
        StreamError::Pald(e)
    }
}

struct Entry {
    engine: IncrementalPald,
    last_touch: Instant,
}

/// Registry of live streaming sessions.
pub struct StreamSessions {
    sessions: Mutex<HashMap<u64, Arc<Mutex<Entry>>>>,
    next_id: AtomicU64,
    idle_timeout: Duration,
    /// Server-policy re-anchor cadence for opened engines.
    reanchor_every: u64,
    opened: AtomicU64,
    closed: AtomicU64,
    updates: AtomicU64,
    reaped: AtomicU64,
}

impl StreamSessions {
    /// Registry whose sessions are reaped after `idle_timeout` without
    /// traffic and re-anchor every `reanchor_every` updates.
    pub fn new(idle_timeout: Duration, reanchor_every: u64) -> StreamSessions {
        StreamSessions {
            sessions: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            idle_timeout,
            reanchor_every,
            opened: AtomicU64::new(0),
            closed: AtomicU64::new(0),
            updates: AtomicU64::new(0),
            reaped: AtomicU64::new(0),
        }
    }

    fn map(&self) -> std::sync::MutexGuard<'_, HashMap<u64, Arc<Mutex<Entry>>>> {
        self.sessions.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn entry(&self, id: u64) -> Result<Arc<Mutex<Entry>>, StreamError> {
        self.map().get(&id).cloned().ok_or(StreamError::NoSuchSession(id))
    }

    /// Open a session seeded with `seed` under the request's options;
    /// `threads` and `validate` are server policy.  Returns
    /// `(session_id, n)`.
    pub fn open(
        &self,
        cfg: &WireConfig,
        seed: &Mat,
        threads: usize,
        validate: bool,
    ) -> Result<(u64, u32), StreamError> {
        let mut b = PaldBuilder::new()
            .algorithm_name(&cfg.algorithm)
            .tie_mode(cfg.tie)
            .semantics(cfg.semantics)
            .threads(Threads::Fixed(threads.max(1)))
            .validation(if validate { Validation::Strict } else { Validation::Skip });
        if cfg.k > 0 {
            b = b.neighborhood(Neighborhood::Knn(cfg.k as usize));
        }
        let mut engine = b.build()?.into_incremental(seed)?;
        if self.reanchor_every > 0 {
            engine.set_reanchor_policy(ReanchorPolicy::EveryN(self.reanchor_every));
        }
        let n = engine.n() as u32;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.map()
            .insert(id, Arc::new(Mutex::new(Entry { engine, last_touch: Instant::now() })));
        self.opened.fetch_add(1, Ordering::Relaxed);
        Ok((id, n))
    }

    fn with_entry<T>(
        &self,
        id: u64,
        f: impl FnOnce(&mut IncrementalPald) -> Result<T, PaldError>,
    ) -> Result<T, StreamError> {
        let entry = self.entry(id)?;
        let mut guard = entry.lock().unwrap_or_else(|p| p.into_inner());
        guard.last_touch = Instant::now();
        f(&mut guard.engine).map_err(StreamError::Pald)
    }

    /// Insert a point (its distances to the session's current points);
    /// returns `(n_after, inserted_index)`.
    pub fn insert(&self, id: u64, row: &[f32]) -> Result<(u32, u32), StreamError> {
        let r = self.with_entry(id, |e| {
            let idx = e.insert_row(row)?;
            Ok((e.n() as u32, idx as u32))
        });
        if r.is_ok() {
            self.updates.fetch_add(1, Ordering::Relaxed);
        }
        r
    }

    /// Remove a point; returns `(n_after, removed_index)`.
    pub fn remove(&self, id: u64, index: u32) -> Result<(u32, u32), StreamError> {
        let r = self.with_entry(id, |e| {
            e.remove(index as usize)?;
            Ok((e.n() as u32, index))
        });
        if r.is_ok() {
            self.updates.fetch_add(1, Ordering::Relaxed);
        }
        r
    }

    /// The session's current cohesion matrix.
    pub fn query(&self, id: u64) -> Result<Mat, StreamError> {
        self.with_entry(id, |e| Ok(e.cohesion()))
    }

    /// Close a session, freeing its engine.
    pub fn close(&self, id: u64) -> Result<(), StreamError> {
        match self.map().remove(&id) {
            Some(_) => {
                self.closed.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            None => Err(StreamError::NoSuchSession(id)),
        }
    }

    /// Drop sessions idle past the registry's timeout; returns how many
    /// were reaped.  Called from the dispatcher tick.
    pub fn reap_idle(&self) -> usize {
        let now = Instant::now();
        let mut map = self.map();
        let stale: Vec<u64> = map
            .iter()
            .filter(|(_, entry)| {
                entry
                    .lock()
                    .map(|g| now.duration_since(g.last_touch) >= self.idle_timeout)
                    .unwrap_or(true)
            })
            .map(|(id, _)| *id)
            .collect();
        for id in &stale {
            map.remove(id);
        }
        self.reaped.fetch_add(stale.len() as u64, Ordering::Relaxed);
        stale.len()
    }

    /// Live session count.
    pub fn len(&self) -> usize {
        self.map().len()
    }

    /// Are no sessions live?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counters for the scrape endpoint:
    /// `(opened, closed, updates, reaped)`.
    pub fn counters(&self) -> (u64, u64, u64, u64) {
        (
            self.opened.load(Ordering::Relaxed),
            self.closed.load(Ordering::Relaxed),
            self.updates.load(Ordering::Relaxed),
            self.reaped.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::distmat;
    use crate::pald::Pald;

    fn registry() -> StreamSessions {
        StreamSessions::new(Duration::from_secs(3600), 0)
    }

    #[test]
    fn session_lifecycle_matches_local_engine() {
        let reg = registry();
        let master = distmat::random_tie_free(12, 9);
        let seed = master.slice_to(10, 10);
        let (id, n) = reg.open(&WireConfig::default(), &seed, 1, true).unwrap();
        assert_eq!(n, 10);

        // Local oracle: the same engine driven directly.
        let mut oracle = Pald::builder().build().unwrap().into_incremental(&seed).unwrap();

        let row10: Vec<f32> = master.row(10)[..10].to_vec();
        let (n1, idx1) = reg.insert(id, &row10).unwrap();
        let oidx1 = oracle.insert_row(&row10).unwrap();
        assert_eq!((n1, idx1 as usize), (11, oidx1));

        let (n2, _) = reg.remove(id, 3).unwrap();
        oracle.remove(3).unwrap();
        assert_eq!(n2, 10);

        let served = reg.query(id).unwrap();
        assert_eq!(served, oracle.cohesion(), "served cohesion must be bit-identical");

        reg.close(id).unwrap();
        assert!(reg.is_empty());
        assert!(matches!(reg.query(id), Err(StreamError::NoSuchSession(_))));
        let (opened, closed, updates, _) = reg.counters();
        assert_eq!((opened, closed, updates), (1, 1, 2));
    }

    #[test]
    fn unknown_ids_and_bad_ops_are_typed() {
        let reg = registry();
        assert!(matches!(reg.insert(99, &[0.0]), Err(StreamError::NoSuchSession(99))));
        assert!(matches!(reg.close(99), Err(StreamError::NoSuchSession(99))));
        let seed = distmat::random_tie_free(8, 2);
        let (id, _) = reg.open(&WireConfig::default(), &seed, 1, true).unwrap();
        // Wrong-length insert row is a PaldError, not a panic.
        assert!(matches!(reg.insert(id, &[1.0, 2.0]), Err(StreamError::Pald(_))));
        // Out-of-range remove likewise.
        assert!(matches!(reg.remove(id, 1000), Err(StreamError::Pald(_))));
        let (code, info, _) = StreamError::NoSuchSession(7).to_wire();
        assert_eq!((code, info), (ErrorCode::NoSuchSession, 7));
    }

    #[test]
    fn idle_sessions_are_reaped() {
        let reg = StreamSessions::new(Duration::from_millis(1), 0);
        let seed = distmat::random_tie_free(8, 2);
        let (id, _) = reg.open(&WireConfig::default(), &seed, 1, true).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(reg.reap_idle(), 1);
        assert!(matches!(reg.query(id), Err(StreamError::NoSuchSession(_))));
        let (.., reaped) = reg.counters();
        assert_eq!(reaped, 1);
    }

    #[test]
    fn truncated_sessions_carry_their_neighborhood() {
        let reg = registry();
        let seed = distmat::random_tie_free(16, 4);
        let cfg = WireConfig { k: 4, ..WireConfig::default() };
        let (id, _) = reg.open(&cfg, &seed, 1, true).unwrap();
        let c = reg.query(id).unwrap();
        assert_eq!(c.rows(), 16);
        // Oracle: same truncated engine locally.
        let oracle = Pald::builder()
            .neighborhood(Neighborhood::Knn(4))
            .build()
            .unwrap()
            .into_incremental(&seed)
            .unwrap();
        assert_eq!(c, oracle.cohesion());
    }
}
