//! `pald-serve` wire protocol: versioned, length-prefixed binary frames
//! over TCP (DESIGN.md §12).
//!
//! Every frame is `[len: u32 LE][version: u8][opcode: u8][request_id:
//! u64 LE][body…]` where `len` counts everything after the 4-byte
//! prefix.  Decoding is total: truncated, oversized, mis-versioned, or
//! structurally malformed frames produce [`PaldError::Protocol`] — never
//! a panic, never an unbounded allocation (the length prefix is checked
//! against the frame cap *before* the payload buffer is sized).
//!
//! Requests cover one-shot compute, explicit batch compute, the
//! streaming-session lifecycle (open / insert / remove / query / close),
//! a `STATS` scrape, and an in-band `SHUTDOWN` drain trigger; responses
//! mirror them plus a typed error frame whose codes map onto
//! [`PaldError`] variants on the client side
//! ([`wire_error_to_pald`]), with retriability carried explicitly so
//! load-shed rejects ([`ErrorCode::Overloaded`], [`ErrorCode::Draining`])
//! are distinguishable from hard failures.

use std::io::Read;

use crate::core::Mat;
use crate::pald::error::PaldError;
use crate::pald::{CohesionSemantics, TieMode};

/// Wire protocol version carried in every frame header.  Version 2
/// added the cohesion-semantics byte to [`WireConfig`]; version-1 peers
/// are rejected with a typed [`PaldError::Protocol`] rather than
/// misparsed.
pub const PROTO_VERSION: u8 = 2;

/// Default cap on one frame's payload (256 MiB — a dense `n = 8192`
/// matrix); larger frames are rejected as [`PaldError::Protocol`]
/// before any allocation.
pub const DEFAULT_MAX_FRAME: usize = 1 << 28;

/// Bytes of header inside the length-prefixed region.
const HEADER_LEN: usize = 1 + 1 + 8;

/// How many consecutive read timeouts mid-frame before the peer is
/// declared stalled (at the serving layer's 250 ms poll this is ~30 s).
const MID_FRAME_RETRIES: usize = 120;

// ---------------------------------------------------------------------
// Opcodes
// ---------------------------------------------------------------------

const OP_COMPUTE: u8 = 0x01;
const OP_COMPUTE_BATCH: u8 = 0x02;
const OP_SESSION_OPEN: u8 = 0x10;
const OP_SESSION_INSERT: u8 = 0x11;
const OP_SESSION_REMOVE: u8 = 0x12;
const OP_SESSION_QUERY: u8 = 0x13;
const OP_SESSION_CLOSE: u8 = 0x14;
const OP_STATS: u8 = 0x20;
const OP_SHUTDOWN: u8 = 0x21;

const OP_R_COHESION: u8 = 0x81;
const OP_R_BATCH: u8 = 0x82;
const OP_R_SESSION_OPENED: u8 = 0x90;
const OP_R_UPDATED: u8 = 0x91;
const OP_R_CLOSED: u8 = 0x92;
const OP_R_STATS: u8 = 0xA0;
const OP_R_SHUTTING_DOWN: u8 = 0xA1;
const OP_R_ERROR: u8 = 0xE0;

// ---------------------------------------------------------------------
// Typed frames
// ---------------------------------------------------------------------

/// Per-request execution options carried on compute and session-open
/// frames — the wire subset of `PaldConfig` (thread budget and block
/// sizes stay server-side policy).
#[derive(Clone, Debug, PartialEq)]
pub struct WireConfig {
    /// Registry algorithm name (`"auto"` for the planner).
    pub algorithm: String,
    /// Distance-tie handling.
    pub tie: TieMode,
    /// Cohesion contribution semantics (DESIGN.md §15).  Rides the
    /// wire as one byte after the tie mode; unknown bytes are a
    /// protocol error, not a silent classic fallback.
    pub semantics: CohesionSemantics,
    /// Truncated-neighborhood size (`0` = dense semantics).
    pub k: u32,
    /// Per-request deadline in milliseconds (`0` = server default).  A
    /// request still queued when its deadline lapses is answered with
    /// [`ErrorCode::Timeout`] instead of being started late.
    pub deadline_ms: u32,
}

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig {
            algorithm: "auto".into(),
            tie: TieMode::Strict,
            semantics: CohesionSemantics::Classic,
            k: 0,
            deadline_ms: 0,
        }
    }
}

/// A client request frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// One-shot cohesion over a dense distance matrix.  Same-shape
    /// one-shots are coalesced server-side into a single
    /// `compute_batch` dispatch (bit-identical results; DESIGN.md §12).
    Compute {
        /// Execution options.
        cfg: WireConfig,
        /// Dense symmetric distance matrix.
        matrix: Mat,
    },
    /// Explicit batch: every matrix runs under the same options, one
    /// response frame carries all outputs in order.
    ComputeBatch {
        /// Execution options shared by the whole batch.
        cfg: WireConfig,
        /// The batch, in response order.
        matrices: Vec<Mat>,
    },
    /// Open a streaming session: a long-lived `IncrementalPald` seeded
    /// with `seed`, addressed by the returned session id.
    SessionOpen {
        /// Execution options for the session's engine.
        cfg: WireConfig,
        /// Seed distance matrix.
        seed: Mat,
    },
    /// Insert one point (its distance row to the current points) into a
    /// streaming session.
    SessionInsert {
        /// Session id from [`Response::SessionOpened`].
        session: u64,
        /// Distances from the new point to the session's current points.
        row: Vec<f32>,
    },
    /// Remove a point from a streaming session.
    SessionRemove {
        /// Session id.
        session: u64,
        /// Index of the point to remove.
        index: u32,
    },
    /// Fetch the session's current cohesion matrix.
    SessionQuery {
        /// Session id.
        session: u64,
    },
    /// Close a streaming session and free its state.
    SessionClose {
        /// Session id.
        session: u64,
    },
    /// Metrics scrape: the same plaintext the HTTP endpoint serves.
    Stats,
    /// Begin a graceful drain (equivalent to SIGTERM): in-flight work
    /// completes, new work is rejected with [`ErrorCode::Draining`].
    Shutdown,
}

/// A server response frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Cohesion matrix for a one-shot compute or a session query.
    Cohesion {
        /// The cohesion matrix.
        matrix: Mat,
    },
    /// Outputs of an explicit batch, in request order.
    Batch {
        /// The cohesion matrices.
        matrices: Vec<Mat>,
    },
    /// A streaming session was opened.
    SessionOpened {
        /// Id addressing the session in later frames.
        session: u64,
        /// Points currently held.
        n: u32,
    },
    /// A session insert/remove was applied.
    Updated {
        /// Points held after the update.
        n: u32,
        /// Index the update touched (the inserted point's index, or the
        /// removed index).
        index: u32,
    },
    /// A session was closed.
    Closed,
    /// Plaintext metrics scrape.
    Stats {
        /// The scrape body.
        text: String,
    },
    /// Drain acknowledged.
    ShuttingDown,
    /// Typed failure.
    Error {
        /// Machine-readable cause.
        code: ErrorCode,
        /// Structured detail for the codes that carry a number
        /// (deadline for timeouts, queue cap for overload); `0`
        /// otherwise.
        info: u64,
        /// Human-readable detail.
        detail: String,
    },
}

/// Machine-readable error causes on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    /// Malformed or mis-versioned frame; the server closes the
    /// connection after sending this.
    Protocol = 1,
    /// The request's deadline lapsed before (or while) it was served.
    Timeout = 2,
    /// Load shed: the bounded admission queue was full.  **Retriable.**
    Overloaded = 3,
    /// The server is draining for shutdown.  **Retriable.**
    Draining = 4,
    /// The request was understood but invalid (e.g. an asymmetric
    /// matrix under strict validation, an unknown algorithm name).
    BadRequest = 5,
    /// No streaming session with the given id.
    NoSuchSession = 6,
    /// Unexpected server-side failure.
    Internal = 7,
    /// The backend shard holding this streaming session died (router
    /// front-tier only; DESIGN.md §14).  **Non-retriable**: the
    /// session's incremental state is gone — replaying updates on
    /// another shard would silently diverge, so the loss is surfaced.
    BackendLost = 8,
    /// A relay/retry budget was exhausted without a success (router
    /// front-tier only): every attempt ended in a retriable shed or a
    /// dead backend.  Non-retriable — the budget was the retry policy.
    RetriesExhausted = 9,
}

impl ErrorCode {
    /// Decode from the wire byte.
    pub fn from_u8(v: u8) -> Option<ErrorCode> {
        Some(match v {
            1 => ErrorCode::Protocol,
            2 => ErrorCode::Timeout,
            3 => ErrorCode::Overloaded,
            4 => ErrorCode::Draining,
            5 => ErrorCode::BadRequest,
            6 => ErrorCode::NoSuchSession,
            7 => ErrorCode::Internal,
            8 => ErrorCode::BackendLost,
            9 => ErrorCode::RetriesExhausted,
            _ => return None,
        })
    }

    /// Should the client back off and retry?  `true` exactly for the
    /// load-shedding rejects: the request was never started.
    pub fn retriable(self) -> bool {
        matches!(self, ErrorCode::Overloaded | ErrorCode::Draining)
    }
}

/// Map a server-side failure onto its wire representation.
pub fn pald_error_to_wire(e: &PaldError) -> (ErrorCode, u64, String) {
    match e {
        PaldError::Protocol { detail } => (ErrorCode::Protocol, 0, detail.clone()),
        PaldError::Timeout { deadline_ms } => {
            (ErrorCode::Timeout, *deadline_ms, e.to_string())
        }
        PaldError::Overloaded { cap, .. } => (ErrorCode::Overloaded, *cap as u64, e.to_string()),
        PaldError::Draining => (ErrorCode::Draining, 0, e.to_string()),
        PaldError::BackendLost { backend } => (ErrorCode::BackendLost, 0, backend.clone()),
        PaldError::RetriesExhausted { attempts, last } => {
            (ErrorCode::RetriesExhausted, *attempts as u64, last.clone())
        }
        other => (ErrorCode::BadRequest, 0, other.to_string()),
    }
}

/// Map a wire error back onto the typed [`PaldError`] surface — the
/// client-side inverse of [`pald_error_to_wire`].  Retriable codes stay
/// retriable ([`PaldError::is_retriable`]).
pub fn wire_error_to_pald(code: ErrorCode, info: u64, detail: String) -> PaldError {
    match code {
        ErrorCode::Protocol => PaldError::Protocol { detail },
        ErrorCode::Timeout => PaldError::Timeout { deadline_ms: info },
        // The queue was full at rejection time, so queued == cap.
        ErrorCode::Overloaded => {
            PaldError::Overloaded { queued: info as usize, cap: info as usize }
        }
        ErrorCode::Draining => PaldError::Draining,
        ErrorCode::BackendLost => PaldError::BackendLost { backend: detail },
        ErrorCode::RetriesExhausted => {
            PaldError::RetriesExhausted { attempts: info as u32, last: detail }
        }
        ErrorCode::BadRequest | ErrorCode::NoSuchSession | ErrorCode::Internal => {
            PaldError::Remote { detail }
        }
    }
}

// ---------------------------------------------------------------------
// Primitive encoding
// ---------------------------------------------------------------------

struct Writer(Vec<u8>);

impl Writer {
    fn new(opcode: u8, request_id: u64) -> Writer {
        let mut w = Writer(Vec::with_capacity(64));
        // Placeholder length patched by finish().
        w.0.extend_from_slice(&[0; 4]);
        w.0.push(PROTO_VERSION);
        w.0.push(opcode);
        w.u64(request_id);
        w
    }

    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }

    fn f32s(&mut self, vs: &[f32]) {
        self.0.reserve(vs.len() * 4);
        for v in vs {
            self.0.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn mat(&mut self, m: &Mat) {
        self.u32(m.rows() as u32);
        self.f32s(m.as_slice());
    }

    fn cfg(&mut self, c: &WireConfig) {
        self.str(&c.algorithm);
        self.u8(match c.tie {
            TieMode::Strict => 0,
            TieMode::Split => 1,
        });
        self.u8(match c.semantics {
            CohesionSemantics::Classic => 0,
            CohesionSemantics::RankBased => 1,
            CohesionSemantics::DistanceWeighted => 2,
        });
        self.u32(c.k);
        self.u32(c.deadline_ms);
    }

    fn finish(mut self) -> Vec<u8> {
        let len = (self.0.len() - 4) as u32;
        self.0[..4].copy_from_slice(&len.to_le_bytes());
        self.0
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn need(&self, bytes: usize) -> Result<(), PaldError> {
        if self.buf.len() - self.pos < bytes {
            return Err(PaldError::protocol(format!(
                "frame body truncated: wanted {bytes} more byte(s), have {}",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }

    fn u8(&mut self) -> Result<u8, PaldError> {
        self.need(1)?;
        let v = self.buf[self.pos];
        self.pos += 1;
        Ok(v)
    }

    fn u32(&mut self) -> Result<u32, PaldError> {
        self.need(4)?;
        let v = u32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        Ok(v)
    }

    fn u64(&mut self) -> Result<u64, PaldError> {
        self.need(8)?;
        let v = u64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        Ok(v)
    }

    fn str(&mut self) -> Result<String, PaldError> {
        let len = self.u32()? as usize;
        self.need(len)?;
        let s = std::str::from_utf8(&self.buf[self.pos..self.pos + len])
            .map_err(|_| PaldError::protocol("string field is not valid UTF-8"))?
            .to_string();
        self.pos += len;
        Ok(s)
    }

    fn f32s(&mut self, count: usize) -> Result<Vec<f32>, PaldError> {
        let bytes = count
            .checked_mul(4)
            .ok_or_else(|| PaldError::protocol("f32 slice length overflows"))?;
        self.need(bytes)?;
        let mut out = Vec::with_capacity(count);
        for i in 0..count {
            let at = self.pos + i * 4;
            out.push(f32::from_le_bytes(self.buf[at..at + 4].try_into().unwrap()));
        }
        self.pos += bytes;
        Ok(out)
    }

    fn mat(&mut self) -> Result<Mat, PaldError> {
        let n = self.u32()? as usize;
        let cells = n
            .checked_mul(n)
            .ok_or_else(|| PaldError::protocol(format!("matrix size n={n} overflows")))?;
        let data = self.f32s(cells)?;
        Ok(Mat::from_vec(n, n, data))
    }

    fn cfg(&mut self) -> Result<WireConfig, PaldError> {
        let algorithm = self.str()?;
        let tie = match self.u8()? {
            0 => TieMode::Strict,
            1 => TieMode::Split,
            other => {
                return Err(PaldError::protocol(format!("unknown tie-mode byte {other}")))
            }
        };
        let semantics = match self.u8()? {
            0 => CohesionSemantics::Classic,
            1 => CohesionSemantics::RankBased,
            2 => CohesionSemantics::DistanceWeighted,
            other => {
                return Err(PaldError::protocol(format!("unknown semantics byte {other}")))
            }
        };
        Ok(WireConfig { algorithm, tie, semantics, k: self.u32()?, deadline_ms: self.u32()? })
    }

    fn done(&self) -> Result<(), PaldError> {
        if self.pos != self.buf.len() {
            return Err(PaldError::protocol(format!(
                "{} trailing byte(s) after frame body",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Frame encode / decode
// ---------------------------------------------------------------------

/// Encode one request frame (length prefix included).
pub fn encode_request(request_id: u64, req: &Request) -> Vec<u8> {
    let mut w;
    match req {
        Request::Compute { cfg, matrix } => {
            w = Writer::new(OP_COMPUTE, request_id);
            w.cfg(cfg);
            w.mat(matrix);
        }
        Request::ComputeBatch { cfg, matrices } => {
            w = Writer::new(OP_COMPUTE_BATCH, request_id);
            w.cfg(cfg);
            w.u32(matrices.len() as u32);
            for m in matrices {
                w.mat(m);
            }
        }
        Request::SessionOpen { cfg, seed } => {
            w = Writer::new(OP_SESSION_OPEN, request_id);
            w.cfg(cfg);
            w.mat(seed);
        }
        Request::SessionInsert { session, row } => {
            w = Writer::new(OP_SESSION_INSERT, request_id);
            w.u64(*session);
            w.u32(row.len() as u32);
            w.f32s(row);
        }
        Request::SessionRemove { session, index } => {
            w = Writer::new(OP_SESSION_REMOVE, request_id);
            w.u64(*session);
            w.u32(*index);
        }
        Request::SessionQuery { session } => {
            w = Writer::new(OP_SESSION_QUERY, request_id);
            w.u64(*session);
        }
        Request::SessionClose { session } => {
            w = Writer::new(OP_SESSION_CLOSE, request_id);
            w.u64(*session);
        }
        Request::Stats => w = Writer::new(OP_STATS, request_id),
        Request::Shutdown => w = Writer::new(OP_SHUTDOWN, request_id),
    }
    w.finish()
}

/// Encode one response frame (length prefix included).
pub fn encode_response(request_id: u64, resp: &Response) -> Vec<u8> {
    let mut w;
    match resp {
        Response::Cohesion { matrix } => {
            w = Writer::new(OP_R_COHESION, request_id);
            w.mat(matrix);
        }
        Response::Batch { matrices } => {
            w = Writer::new(OP_R_BATCH, request_id);
            w.u32(matrices.len() as u32);
            for m in matrices {
                w.mat(m);
            }
        }
        Response::SessionOpened { session, n } => {
            w = Writer::new(OP_R_SESSION_OPENED, request_id);
            w.u64(*session);
            w.u32(*n);
        }
        Response::Updated { n, index } => {
            w = Writer::new(OP_R_UPDATED, request_id);
            w.u32(*n);
            w.u32(*index);
        }
        Response::Closed => w = Writer::new(OP_R_CLOSED, request_id),
        Response::Stats { text } => {
            w = Writer::new(OP_R_STATS, request_id);
            w.str(text);
        }
        Response::ShuttingDown => w = Writer::new(OP_R_SHUTTING_DOWN, request_id),
        Response::Error { code, info, detail } => {
            w = Writer::new(OP_R_ERROR, request_id);
            w.u8(*code as u8);
            w.u8(code.retriable() as u8);
            w.u64(*info);
            w.str(detail);
        }
    }
    w.finish()
}

/// A frame as read off the wire, before typed decoding.
#[derive(Clone, Debug)]
pub struct RawFrame {
    /// Protocol version from the header (always [`PROTO_VERSION`] after
    /// a successful read).
    pub version: u8,
    /// Frame opcode.
    pub opcode: u8,
    /// Request correlation id.
    pub request_id: u64,
    /// Opcode-specific body.
    pub payload: Vec<u8>,
}

/// Outcome of one [`read_frame`] attempt on a (possibly timeout-polled)
/// stream.
#[derive(Debug)]
pub enum FrameRead {
    /// A complete frame arrived.
    Frame(RawFrame),
    /// The peer closed the connection at a clean frame boundary.
    Eof,
    /// A read timeout fired before any byte of a new frame arrived —
    /// the connection is idle (lets pollers check a drain flag).
    Idle,
}

enum Fill {
    Done,
    CleanEof,
    Idle,
    TruncatedEof,
}

/// Fill `buf`, tolerating read-timeout polls.  `retries` bounds how many
/// consecutive timeouts are allowed once the first byte has arrived.
fn fill(r: &mut impl Read, buf: &mut [u8], mut retries: usize) -> std::io::Result<Fill> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Ok(if got == 0 { Fill::CleanEof } else { Fill::TruncatedEof });
            }
            Ok(m) => got += m,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if got == 0 {
                    return Ok(Fill::Idle);
                }
                if retries == 0 {
                    return Ok(Fill::TruncatedEof);
                }
                retries -= 1;
            }
            Err(e) => return Err(e),
        }
    }
    Ok(Fill::Done)
}

/// Read one frame, treating a read-timeout before any byte as
/// [`FrameRead::Idle`].  Oversized (`len > max_frame`), truncated, and
/// mis-versioned frames are [`PaldError::Protocol`].
pub fn read_frame(r: &mut impl Read, max_frame: usize) -> Result<FrameRead, PaldError> {
    let mut len4 = [0u8; 4];
    match fill(r, &mut len4, MID_FRAME_RETRIES).map_err(io_protocol)? {
        Fill::Done => {}
        Fill::CleanEof => return Ok(FrameRead::Eof),
        Fill::Idle => return Ok(FrameRead::Idle),
        Fill::TruncatedEof => return Err(PaldError::protocol("truncated frame header")),
    }
    read_frame_after_len(r, len4, max_frame)
}

/// [`read_frame`] when the 4-byte length prefix was already consumed
/// (the serving layer sniffs those bytes to multiplex HTTP scrapes onto
/// the same port).
pub fn read_frame_after_len(
    r: &mut impl Read,
    len4: [u8; 4],
    max_frame: usize,
) -> Result<FrameRead, PaldError> {
    let len = u32::from_le_bytes(len4) as usize;
    if len < HEADER_LEN {
        return Err(PaldError::protocol(format!(
            "frame length {len} is shorter than the {HEADER_LEN}-byte header"
        )));
    }
    if len > max_frame {
        return Err(PaldError::protocol(format!(
            "oversized frame: {len} bytes exceeds the {max_frame}-byte cap"
        )));
    }
    let mut buf = vec![0u8; len];
    match fill(r, &mut buf, MID_FRAME_RETRIES).map_err(io_protocol)? {
        Fill::Done => {}
        Fill::CleanEof | Fill::Idle | Fill::TruncatedEof => {
            return Err(PaldError::protocol("frame truncated mid-body"));
        }
    }
    let version = buf[0];
    if version != PROTO_VERSION {
        return Err(PaldError::protocol(format!(
            "unsupported protocol version {version} (this build speaks {PROTO_VERSION})"
        )));
    }
    let opcode = buf[1];
    let request_id = u64::from_le_bytes(buf[2..10].try_into().unwrap());
    Ok(FrameRead::Frame(RawFrame { version, opcode, request_id, payload: buf[10..].to_vec() }))
}

fn io_protocol(e: std::io::Error) -> PaldError {
    PaldError::protocol(format!("io error mid-frame: {e}"))
}

/// Decode a raw frame as a request (server side).
pub fn decode_request(frame: &RawFrame) -> Result<Request, PaldError> {
    let mut r = Reader::new(&frame.payload);
    let req = match frame.opcode {
        OP_COMPUTE => Request::Compute { cfg: r.cfg()?, matrix: r.mat()? },
        OP_COMPUTE_BATCH => {
            let cfg = r.cfg()?;
            let count = r.u32()? as usize;
            let mut matrices = Vec::new();
            for _ in 0..count {
                matrices.push(r.mat()?);
            }
            Request::ComputeBatch { cfg, matrices }
        }
        OP_SESSION_OPEN => Request::SessionOpen { cfg: r.cfg()?, seed: r.mat()? },
        OP_SESSION_INSERT => {
            let session = r.u64()?;
            let len = r.u32()? as usize;
            Request::SessionInsert { session, row: r.f32s(len)? }
        }
        OP_SESSION_REMOVE => Request::SessionRemove { session: r.u64()?, index: r.u32()? },
        OP_SESSION_QUERY => Request::SessionQuery { session: r.u64()? },
        OP_SESSION_CLOSE => Request::SessionClose { session: r.u64()? },
        OP_STATS => Request::Stats,
        OP_SHUTDOWN => Request::Shutdown,
        other => {
            return Err(PaldError::protocol(format!("unknown request opcode 0x{other:02x}")))
        }
    };
    r.done()?;
    Ok(req)
}

/// Decode a raw frame as a response (client side).
pub fn decode_response(frame: &RawFrame) -> Result<Response, PaldError> {
    let mut r = Reader::new(&frame.payload);
    let resp = match frame.opcode {
        OP_R_COHESION => Response::Cohesion { matrix: r.mat()? },
        OP_R_BATCH => {
            let count = r.u32()? as usize;
            let mut matrices = Vec::new();
            for _ in 0..count {
                matrices.push(r.mat()?);
            }
            Response::Batch { matrices }
        }
        OP_R_SESSION_OPENED => Response::SessionOpened { session: r.u64()?, n: r.u32()? },
        OP_R_UPDATED => Response::Updated { n: r.u32()?, index: r.u32()? },
        OP_R_CLOSED => Response::Closed,
        OP_R_STATS => Response::Stats { text: r.str()? },
        OP_R_SHUTTING_DOWN => Response::ShuttingDown,
        OP_R_ERROR => {
            let code_byte = r.u8()?;
            let code = ErrorCode::from_u8(code_byte).ok_or_else(|| {
                PaldError::protocol(format!("unknown error code {code_byte}"))
            })?;
            let _retriable = r.u8()?; // carried for non-Rust clients
            Response::Error { code, info: r.u64()?, detail: r.str()? }
        }
        other => {
            return Err(PaldError::protocol(format!("unknown response opcode 0x{other:02x}")))
        }
    };
    r.done()?;
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn read_one(bytes: &[u8]) -> Result<RawFrame, PaldError> {
        match read_frame(&mut Cursor::new(bytes), DEFAULT_MAX_FRAME)? {
            FrameRead::Frame(f) => Ok(f),
            other => Err(PaldError::protocol(format!("expected frame, got {other:?}"))),
        }
    }

    #[test]
    fn request_round_trip() {
        let m = Mat::from_fn(3, 3, |i, j| (i + j) as f32);
        let cfg = WireConfig {
            algorithm: "opt-pairwise".into(),
            tie: TieMode::Split,
            semantics: CohesionSemantics::DistanceWeighted,
            k: 4,
            deadline_ms: 250,
        };
        let reqs = vec![
            Request::Compute { cfg: cfg.clone(), matrix: m.clone() },
            Request::ComputeBatch { cfg: cfg.clone(), matrices: vec![m.clone(), m.clone()] },
            Request::SessionOpen { cfg, seed: m.clone() },
            Request::SessionInsert { session: 7, row: vec![0.5, 1.5, 2.5] },
            Request::SessionRemove { session: 7, index: 2 },
            Request::SessionQuery { session: 7 },
            Request::SessionClose { session: 7 },
            Request::Stats,
            Request::Shutdown,
        ];
        for (i, req) in reqs.iter().enumerate() {
            let bytes = encode_request(i as u64, req);
            let frame = read_one(&bytes).unwrap();
            assert_eq!(frame.request_id, i as u64);
            assert_eq!(&decode_request(&frame).unwrap(), req, "frame {i}");
        }
    }

    #[test]
    fn response_round_trip() {
        let m = Mat::from_fn(2, 2, |i, j| (i * 2 + j) as f32);
        let resps = vec![
            Response::Cohesion { matrix: m.clone() },
            Response::Batch { matrices: vec![m.clone(), m] },
            Response::SessionOpened { session: 11, n: 20 },
            Response::Updated { n: 21, index: 20 },
            Response::Closed,
            Response::Stats { text: "paldx_jobs_total 3\n".into() },
            Response::ShuttingDown,
            Response::Error {
                code: ErrorCode::Overloaded,
                info: 64,
                detail: "queue full".into(),
            },
        ];
        for (i, resp) in resps.iter().enumerate() {
            let bytes = encode_response(1000 + i as u64, resp);
            let frame = read_one(&bytes).unwrap();
            assert_eq!(frame.request_id, 1000 + i as u64);
            assert_eq!(&decode_response(&frame).unwrap(), resp, "frame {i}");
        }
    }

    #[test]
    fn error_mapping_round_trips_retriability() {
        for e in [
            PaldError::protocol("x"),
            PaldError::Timeout { deadline_ms: 99 },
            PaldError::Overloaded { queued: 8, cap: 8 },
            PaldError::Draining,
            PaldError::TooSmall { n: 1 },
            PaldError::BackendLost { backend: "127.0.0.1:7465".into() },
            PaldError::RetriesExhausted { attempts: 4, last: "draining".into() },
        ] {
            let (code, info, detail) = pald_error_to_wire(&e);
            let back = wire_error_to_pald(code, info, detail);
            assert_eq!(e.is_retriable(), back.is_retriable(), "{e}");
            assert_eq!(e.is_retriable(), code.retriable(), "{e}");
        }
        // Structured payloads survive.
        let (c, info, d) = pald_error_to_wire(&PaldError::Timeout { deadline_ms: 250 });
        assert!(matches!(wire_error_to_pald(c, info, d), PaldError::Timeout { deadline_ms: 250 }));
        // The router-tier codes carry their structure across the wire.
        let (c, info, d) =
            pald_error_to_wire(&PaldError::BackendLost { backend: "10.1.2.3:7465".into() });
        assert_eq!(c, ErrorCode::BackendLost);
        match wire_error_to_pald(c, info, d) {
            PaldError::BackendLost { backend } => assert_eq!(backend, "10.1.2.3:7465"),
            other => panic!("expected BackendLost, got {other:?}"),
        }
        let (c, info, d) = pald_error_to_wire(&PaldError::RetriesExhausted {
            attempts: 5,
            last: "overloaded".into(),
        });
        assert_eq!((c, info), (ErrorCode::RetriesExhausted, 5));
        match wire_error_to_pald(c, info, d) {
            PaldError::RetriesExhausted { attempts: 5, last } => {
                assert_eq!(last, "overloaded")
            }
            other => panic!("expected RetriesExhausted, got {other:?}"),
        }
    }

    #[test]
    fn oversized_frame_is_rejected_before_allocation() {
        let mut bytes = encode_request(1, &Request::Stats);
        bytes[..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        let err = read_frame(&mut Cursor::new(&bytes), 1 << 20).unwrap_err();
        assert!(matches!(err, PaldError::Protocol { .. }), "{err}");
        assert!(err.to_string().contains("oversized"), "{err}");
    }

    #[test]
    fn bad_version_and_undersized_header_are_typed() {
        let mut bytes = encode_request(1, &Request::Stats);
        bytes[4] = 9; // version
        assert!(matches!(read_one(&bytes), Err(PaldError::Protocol { .. })));
        let short = 3u32.to_le_bytes();
        let mut buf = short.to_vec();
        buf.extend_from_slice(&[0, 0, 0]);
        assert!(matches!(read_one(&buf), Err(PaldError::Protocol { .. })));
    }

    #[test]
    fn every_truncation_is_an_error_never_a_panic() {
        let m = Mat::from_fn(3, 3, |i, j| (i + j) as f32);
        let bytes = encode_request(
            5,
            &Request::Compute { cfg: WireConfig::default(), matrix: m },
        );
        for cut in 0..bytes.len() {
            let r = read_frame(&mut Cursor::new(&bytes[..cut]), DEFAULT_MAX_FRAME);
            match r {
                Ok(FrameRead::Eof) => assert_eq!(cut, 0),
                Ok(other) => panic!("cut {cut}: unexpected {other:?}"),
                Err(e) => assert!(matches!(e, PaldError::Protocol { .. }), "cut {cut}: {e}"),
            }
        }
        // Garbage bodies decode to typed errors too.
        let garbage = RawFrame { version: PROTO_VERSION, opcode: 0x01, request_id: 0, payload: vec![0xff; 7] };
        assert!(matches!(decode_request(&garbage), Err(PaldError::Protocol { .. })));
        let unknown = RawFrame { version: PROTO_VERSION, opcode: 0x7f, request_id: 0, payload: vec![] };
        assert!(matches!(decode_request(&unknown), Err(PaldError::Protocol { .. })));
        let trailing = {
            let mut bytes = encode_request(1, &Request::SessionQuery { session: 3 });
            bytes.extend_from_slice(&[1, 2, 3]);
            let len = (bytes.len() - 4) as u32;
            bytes[..4].copy_from_slice(&len.to_le_bytes());
            bytes
        };
        let frame = read_one(&trailing).unwrap();
        assert!(matches!(decode_request(&frame), Err(PaldError::Protocol { .. })));
    }

    #[test]
    fn matrix_size_overflow_is_guarded() {
        // A frame claiming an n whose n² overflows usize must fail
        // cleanly in the size check, not allocate.
        let mut w = Writer::new(OP_SESSION_QUERY, 0);
        w.u64(1);
        let mut bytes = w.finish();
        // Rewrite as a Compute frame with a huge matrix n and no data.
        bytes[5] = OP_COMPUTE;
        let frame = read_one(&bytes).unwrap();
        assert!(decode_request(&frame).is_err());
    }
}
