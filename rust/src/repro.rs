//! Reproduction drivers: one function per paper table/figure, each
//! returning a printable [`Table`] with the same rows/series the paper
//! reports.  Shared by the CLI (`paldx repro --exp ...`) and the bench
//! binaries (`cargo bench`).
//!
//! Default problem sizes are offline-friendly; set `PALDX_FULL=1` for the
//! paper's sizes (n = 2048..8192 — hours of compute at paper scale).

use crate::bench::{bench, fmt_secs, fmt_speedup, BenchOpts, Stats, Table};
use crate::core::Mat;
use crate::data::{distmat, graph};
use crate::pald::{self, ops, Algorithm, PaldConfig, TieMode};
use crate::sim::machine::MachineParams;
use crate::sim::{cache, scaling, traffic};

fn stats_alg(d: &Mat, alg: Algorithm, block: usize, block2: usize, opts: &BenchOpts) -> Stats {
    let cfg = PaldConfig { algorithm: alg, block, block2, threads: 1, ..Default::default() };
    // Workspace-reusing timing loop: steady-state serving cost, not
    // first-call allocation cost.
    let mut session = pald::Session::new(cfg).expect("session");
    let mut out = Mat::zeros(d.rows(), d.rows());
    bench(opts, || {
        session.compute_into(d, &mut out).expect("compute");
        std::hint::black_box(out.sum());
    })
}

fn time_alg(d: &Mat, alg: Algorithm, block: usize, block2: usize, opts: &BenchOpts) -> f64 {
    stats_alg(d, alg, block, block2, opts).mean
}

/// Figure 3: speedups of the optimization ladder, relative to the previous
/// rung (paper convention) plus cumulative vs naive pairwise.
pub fn fig3(n: usize, opts: &BenchOpts) -> Table {
    let d = distmat::random_tie_free(n, 2023);
    let b = 128.min(n);
    let ladder: Vec<(&str, Algorithm, usize, usize)> = vec![
        ("naive pairwise", Algorithm::NaivePairwise, 0, 0),
        ("naive triplet", Algorithm::NaiveTriplet, 0, 0),
        ("blocked pairwise", Algorithm::BlockedPairwise, b, 0),
        ("blocked triplet", Algorithm::BlockedTriplet, b, b),
        ("branch-avoid pairwise", Algorithm::BranchFreePairwise, 0, 0),
        ("branch-avoid triplet", Algorithm::BranchFreeTriplet, 0, 0),
        ("opt pairwise (blk+bf+intU)", Algorithm::OptimizedPairwise, b, 0),
        ("opt triplet (blk+bf+intU)", Algorithm::OptimizedTriplet, b, b / 2),
    ];
    let mut table = Table::new(
        &format!("Figure 3 — optimization ladder speedups (n={n})"),
        &["variant", "time", "vs previous", "vs naive pairwise"],
    );
    let mut prev = f64::NAN;
    let mut naive_pw = f64::NAN;
    for (name, alg, blk, blk2) in ladder {
        let st = stats_alg(&d, alg, blk, blk2, opts);
        table.stat(alg.name(), st);
        let t = st.mean;
        if naive_pw.is_nan() {
            naive_pw = t;
        }
        let vs_prev = if prev.is_nan() { 1.0 } else { prev / t };
        table.row(vec![
            name.into(),
            fmt_secs(t),
            fmt_speedup(vs_prev),
            fmt_speedup(naive_pw / t),
        ]);
        prev = t;
    }
    table
}

/// Figure 4: block-size tuning sweeps for optimized pairwise and triplet.
pub fn fig4(n: usize, opts: &BenchOpts) -> (Table, Table) {
    let d = distmat::random_tie_free(n, 44);
    let naive_pw = time_alg(&d, Algorithm::NaivePairwise, 0, 0, opts);
    let naive_tr = time_alg(&d, Algorithm::NaiveTriplet, 0, 0, opts);

    let mut pw = Table::new(
        &format!("Figure 4 (top) — pairwise block-size tuning (n={n})"),
        &["b", "time", "speedup vs naive pairwise"],
    );
    let mut b = 32usize;
    while b <= n.min(1024) {
        let st = stats_alg(&d, Algorithm::OptimizedPairwise, b, 0, opts);
        let t = st.mean;
        pw.stat(format!("opt-pairwise/b={b}"), st);
        pw.row(vec![b.to_string(), fmt_secs(t), fmt_speedup(naive_pw / t)]);
        b *= 2;
    }

    let mut tr = Table::new(
        &format!("Figure 4 (bottom) — triplet block-size tuning (n={n})"),
        &["b-hat", "b-tilde", "time", "speedup vs naive triplet"],
    );
    let mut bh = 32usize;
    while bh <= n.min(512) {
        let mut bt = 32usize;
        while bt <= n.min(512) {
            let st = stats_alg(&d, Algorithm::OptimizedTriplet, bh, bt, opts);
            let t = st.mean;
            tr.stat(format!("opt-triplet/bh={bh},bt={bt}"), st);
            tr.row(vec![
                bh.to_string(),
                bt.to_string(),
                fmt_secs(t),
                fmt_speedup(naive_tr / t),
            ]);
            bt *= 4;
        }
        bh *= 4;
    }
    (pw, tr)
}

/// Table 1: optimized pairwise vs optimized triplet across matrix sizes.
pub fn table1(sizes: &[usize], opts: &BenchOpts) -> Table {
    let mut table = Table::new(
        "Table 1 — running time (s): optimized pairwise vs triplet",
        &["n", "pairwise", "triplet", "winner (speedup)"],
    );
    for &n in sizes {
        let d = distmat::random_tie_free(n, n as u64);
        let sp = stats_alg(&d, Algorithm::OptimizedPairwise, 128.min(n), 0, opts);
        let st = stats_alg(&d, Algorithm::OptimizedTriplet, 256.min(n), 128.min(n), opts);
        table.stat(format!("opt-pairwise/n={n}"), sp);
        table.stat(format!("opt-triplet/n={n}"), st);
        let (tp, tt) = (sp.mean, st.mean);
        let winner = if tp < tt {
            format!("pairwise ({})", fmt_speedup(tt / tp))
        } else {
            format!("triplet ({})", fmt_speedup(tp / tt))
        };
        table.row(vec![n.to_string(), format!("{tp:.5}"), format!("{tt:.5}"), winner]);
    }
    table
}

fn machine() -> MachineParams {
    // Calibrated against this core when PALDX_CALIBRATE=1; otherwise the
    // paper's Xeon constants (faster, and the paper's testbed).
    if std::env::var("PALDX_CALIBRATE").map(|v| v == "1").unwrap_or(false) {
        MachineParams::calibrated(true)
    } else {
        MachineParams::xeon_6226r()
    }
}

/// Figure 9: NUMA speedups at p=32 (machine-model simulation).
pub fn fig9(sizes: &[u64]) -> Table {
    let mp = machine();
    let mut table = Table::new(
        "Figure 9 — NUMA speedup over unbound OpenMP pairwise (p=32, simulated)",
        &["n", "thread binding", "thread+memory binding"],
    );
    for (n, tb, tmb) in scaling::fig9_numa_speedups(&mp, sizes, 32) {
        table.row(vec![n.to_string(), fmt_speedup(tb), fmt_speedup(tmb)]);
    }
    table
}

/// Figure 10: strong-scaling efficiency (simulated).
pub fn fig10(sizes: &[u64], pairwise: bool) -> Table {
    let mp = machine();
    let threads = [1usize, 2, 4, 8, 16, 32];
    let name = if pairwise { "pairwise" } else { "triplet" };
    let mut table = Table::new(
        &format!("Figure 10 — {name} strong-scaling efficiency (simulated)"),
        &["n", "p", "eff (no NUMA)", "eff (NUMA)"],
    );
    let no = scaling::fig10_strong_scaling(&mp, sizes, &threads, pairwise, false);
    let yes = scaling::fig10_strong_scaling(&mp, sizes, &threads, pairwise, true);
    for (sn, sy) in no.iter().zip(&yes) {
        for (i, &p) in sn.threads.iter().enumerate() {
            table.row(vec![
                sn.n.to_string(),
                p.to_string(),
                format!("{:.1}%", 100.0 * sn.efficiency[i]),
                format!("{:.1}%", 100.0 * sy.efficiency[i]),
            ]);
        }
    }
    table
}

/// Figure 11: weak-scaling efficiency (simulated).
pub fn fig11(n1_sizes: &[u64], pairwise: bool) -> Table {
    let mp = machine();
    let threads = [1usize, 2, 4, 8, 16, 32];
    let name = if pairwise { "pairwise" } else { "triplet" };
    let mut table = Table::new(
        &format!("Figure 11 — {name} weak-scaling efficiency (simulated, n^3/p fixed)"),
        &["n1", "p", "eff (no NUMA)", "eff (NUMA)"],
    );
    let no = scaling::fig11_weak_scaling(&mp, n1_sizes, &threads, pairwise, false);
    let yes = scaling::fig11_weak_scaling(&mp, n1_sizes, &threads, pairwise, true);
    for (sn, sy) in no.iter().zip(&yes) {
        for (i, &p) in sn.threads.iter().enumerate() {
            table.row(vec![
                sn.n.to_string(),
                p.to_string(),
                format!("{:.1}%", 100.0 * sn.efficiency[i]),
                format!("{:.1}%", 100.0 * sy.efficiency[i]),
            ]);
        }
    }
    table
}

/// Figure 13: runtime breakdown by phase (p = 1 measured + p > 1 simulated).
pub fn fig13(n: u64) -> Table {
    let mp = machine();
    let mut table = Table::new(
        &format!("Figure 13 — runtime fraction by phase (n={n}, simulated)"),
        &["algorithm", "p", "focus %", "cohesion %", "overhead %"],
    );
    for pairwise in [true, false] {
        let name = if pairwise { "pairwise" } else { "triplet" };
        for (p, bd) in scaling::fig13_breakdown(&mp, n, &[1, 2, 4, 8, 16, 32], pairwise) {
            let tot = bd.total();
            table.row(vec![
                name.into(),
                p.to_string(),
                format!("{:.1}", 100.0 * bd.focus_s / tot),
                format!("{:.1}", 100.0 * bd.cohesion_s / tot),
                format!("{:.1}", 100.0 * bd.overhead_s / tot),
            ]);
        }
    }
    table
}

/// Table 2: SNAP-like collaboration networks — measured sequential time at
/// a scale factor + simulated p=32 speedup (full sizes under PALDX_FULL=1).
pub fn table2(scale_div: usize, opts: &BenchOpts) -> Table {
    let mp = machine();
    let datasets: [(&str, usize); 3] =
        [("ca-GrQc", 5242), ("ca-HepPh", 12008), ("ca-CondMat", 23133)];
    let mut table = Table::new(
        &format!(
            "Table 2 — collaboration networks (synthetic SNAP substitutes, 1/{scale_div} scale)"
        ),
        &["dataset", "n (run)", "seq time", "sim p=32 speedup", "sim p=32 time"],
    );
    for (name, full_n) in datasets {
        let n = (full_n / scale_div).max(64);
        let g = graph::collaboration_network(n, 0xC0FFEE);
        let (lcc, _) = g.largest_component();
        let d = lcc.apsp(true);
        let n_run = d.rows();
        let s_seq = stats_alg(&d, Algorithm::OptimizedPairwise, 128.min(n_run), 0, opts);
        table.stat(format!("opt-pairwise/{name}"), s_seq);
        let t_seq = s_seq.mean;
        let speedup = scaling::predicted_speedup(&mp, n_run as u64, 32, true, true);
        table.row(vec![
            name.into(),
            n_run.to_string(),
            format!("{t_seq:.4}"),
            fmt_speedup(speedup),
            format!("{:.4}", t_seq / speedup),
        ]);
    }
    table
}

/// Appendix A: percentage of single-core peak for the optimized variants.
pub fn appendix_peak(n: usize, opts: &BenchOpts) -> Table {
    let d = distmat::random_tie_free(n, 99);
    let mut table = Table::new(
        &format!("Appendix A — %% of single-core peak (n={n})"),
        &["algorithm", "normalized ops", "time", "Gops/s", "% of calibrated peak"],
    );
    // Calibrated peak: the branch-free cohesion kernel at L1-resident size
    // approximates this core's achievable comparison/FMA throughput.
    let peak = calibrated_peak_ops_per_sec();
    for (name, alg, f) in [
        (
            "opt pairwise",
            Algorithm::OptimizedPairwise,
            ops::pairwise_ops(n as u64).normalized(),
        ),
        (
            "opt triplet",
            Algorithm::OptimizedTriplet,
            ops::triplet_ops(n as u64).normalized(),
        ),
    ] {
        let st = stats_alg(&d, alg, 128.min(n), 128.min(n), opts);
        table.stat(alg.name(), st);
        let t = st.mean;
        let rate = f / t;
        table.row(vec![
            name.into(),
            format!("{:.3e}", f),
            fmt_secs(t),
            format!("{:.2}", rate / 1e9),
            format!("{:.1}%", 100.0 * rate / peak),
        ]);
    }
    table
}

/// Micro-measured achievable op rate on this core (normalized ops/s): the
/// pairwise branch-free kernels on an L1-resident problem.
pub fn calibrated_peak_ops_per_sec() -> f64 {
    use std::time::Instant;
    let n = 128;
    let d = distmat::random_tie_free(n, 1);
    let cfg = PaldConfig { algorithm: Algorithm::OptimizedPairwise, block: n, ..Default::default() };
    let mut session = pald::Session::new(cfg).expect("peak calib session");
    let mut out = Mat::zeros(n, n);
    // warmup + best of 5
    let mut best = f64::INFINITY;
    for _ in 0..6 {
        let t0 = Instant::now();
        session.compute_into(&d, &mut out).expect("peak calib");
        std::hint::black_box(out.sum());
        best = best.min(t0.elapsed().as_secs_f64());
    }
    ops::pairwise_ops(n as u64).normalized() / best
}

/// Section 4 validation: measured traffic vs Theorems 4.1/4.2 and the 3NL
/// lower bound, plus an LRU-cache-simulation cross-check at small n.
pub fn bounds() -> Table {
    let mut table = Table::new(
        "Section 4 — communication: measured words vs theory and lower bound",
        &["quantity", "n", "M (words)", "words", "x over lower bound"],
    );
    let m = 1u64 << 14;
    for &n in &[1024u64, 2048, 4096] {
        let b = traffic::pairwise_opt_block(m);
        let wp = traffic::pairwise_words_exact(n, b);
        let (bh, bt) = traffic::triplet_opt_blocks(m);
        let wt = traffic::triplet_words_exact(n, bh, bt);
        table.row(vec![
            "pairwise (block model)".into(),
            n.to_string(),
            m.to_string(),
            format!("{wp:.3e}"),
            format!("{:.2} (theory 5.66)", traffic::vs_lower_bound(wp, n, m)),
        ]);
        table.row(vec![
            "triplet (block model)".into(),
            n.to_string(),
            m.to_string(),
            format!("{wt:.3e}"),
            format!("{:.2} (theory 9.38)", traffic::vs_lower_bound(wt, n, m)),
        ]);
    }
    // Cache-simulation cross-check at small n.
    let (n, cap) = (96u64, 4096usize);
    let mut sim = cache::Cache::new(cap, 8, 8);
    sim.run(cache::pairwise_trace(n as usize, 16));
    table.row(vec![
        "pairwise (LRU cache sim, b=16)".into(),
        n.to_string(),
        cap.to_string(),
        format!("{:.3e}", sim.words_moved() as f64),
        format!("{:.2}", traffic::vs_lower_bound(sim.words_moved(), n, cap as u64)),
    ]);
    table
}

/// Ablation (paper Appendix B + Section 5): tie handling cost and the
/// hybrid (triplet-focus + pairwise-cohesion) variant the paper proposes
/// as future work.
pub fn ablation(n: usize, opts: &BenchOpts) -> Table {
    let d = distmat::random_tie_free(n, 314);
    let mut table = Table::new(
        &format!("Ablation — tie modes and Appendix B hybrid (n={n})"),
        &["variant", "strict", "split (exact ties)", "split cost"],
    );
    for (name, alg) in [
        ("opt pairwise", Algorithm::OptimizedPairwise),
        ("opt triplet", Algorithm::OptimizedTriplet),
        ("hybrid (Appdx B)", Algorithm::Hybrid),
    ] {
        let cfg = |tie| PaldConfig {
            algorithm: alg,
            tie_mode: tie,
            block: 128.min(n),
            block2: 128.min(n),
            threads: 1,
            ..Default::default()
        };
        let mut out = Mat::zeros(n, n);
        let mut sess_strict = pald::Session::new(cfg(TieMode::Strict)).expect("session");
        let s_strict = bench(opts, || {
            sess_strict.compute_into(&d, &mut out).expect("compute");
            std::hint::black_box(out.sum());
        });
        let mut sess_split = pald::Session::new(cfg(TieMode::Split)).expect("session");
        let s_split = bench(opts, || {
            sess_split.compute_into(&d, &mut out).expect("compute");
            std::hint::black_box(out.sum());
        });
        table.stat(format!("{}/strict", alg.name()), s_strict);
        table.stat(format!("{}/split", alg.name()), s_split);
        let (t_strict, t_split) = (s_strict.mean, s_split.mean);
        table.row(vec![
            name.into(),
            fmt_secs(t_strict),
            fmt_secs(t_split),
            fmt_speedup(t_split / t_strict),
        ]);
    }
    table
}

/// Does `artifacts` hold a compiled PJRT artifact set ([`xla_check`]
/// needs `manifest.json` from `python -m compile.aot`)?  The repro and
/// bench entry points gate on this so artifact-less hosts record an
/// explicit skip instead of failing.
pub fn xla_artifacts_present(artifacts: &std::path::Path) -> bool {
    artifacts.join("manifest.json").is_file()
}

/// Cross-backend validation: native vs XLA artifact, with throughput.
pub fn xla_check(n: usize, artifacts: &std::path::Path) -> anyhow::Result<Table> {
    use crate::coordinator::{Coordinator, Job};
    use crate::pald::Backend;

    let d = distmat::random_tie_free(n, 5);
    let mut coord = Coordinator::new();
    let native_job = Job {
        config: PaldConfig { algorithm: Algorithm::OptimizedTriplet, ..Default::default() },
        artifacts_dir: artifacts.to_path_buf(),
    };
    let xla_job = Job {
        config: PaldConfig { backend: Backend::Xla, tie_mode: TieMode::Strict, ..Default::default() },
        artifacts_dir: artifacts.to_path_buf(),
    };
    let t0 = std::time::Instant::now();
    let c_native = coord.run(&d, &native_job)?;
    let t_native = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let c_xla = coord.run(&d, &xla_job)?;
    let t_xla_cold = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let _ = coord.run(&d, &xla_job)?;
    let t_xla_warm = t0.elapsed().as_secs_f64();

    let maxdiff = c_native.max_abs_diff(&c_xla);
    anyhow::ensure!(
        c_native.allclose(&c_xla, 1e-4, 1e-5),
        "XLA and native disagree: maxdiff={maxdiff}"
    );
    let mut table = Table::new(
        &format!("Cross-backend check (n={n}): native vs AOT XLA artifact"),
        &["backend", "time", "max |Δ| vs native"],
    );
    table.row(vec!["native opt-triplet".into(), fmt_secs(t_native), "0".into()]);
    table.row(vec!["xla (cold, incl. compile)".into(), fmt_secs(t_xla_cold), format!("{maxdiff:.2e}")]);
    table.row(vec!["xla (warm)".into(), fmt_secs(t_xla_warm), format!("{maxdiff:.2e}")]);
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> BenchOpts {
        BenchOpts { warmup: 0, trials: 1, budget_s: 30.0 }
    }

    #[test]
    fn fig3_runs_small() {
        let t = fig3(64, &quick_opts());
        assert_eq!(t.rows.len(), 8);
        assert_eq!(t.stats.len(), 8, "fig3 must carry raw stats for the JSON report");
        assert!(t.stats.iter().all(|e| e.stats.mean > 0.0));
    }

    #[test]
    fn table1_runs_small() {
        let t = table1(&[32, 64], &quick_opts());
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn sim_tables_have_rows() {
        assert!(!fig9(&[2048]).rows.is_empty());
        assert!(!fig10(&[2048], true).rows.is_empty());
        assert!(!fig11(&[2048], false).rows.is_empty());
        assert!(!fig13(2048).rows.is_empty());
        assert!(!bounds().rows.is_empty());
    }

    #[test]
    fn table2_tiny_scale() {
        let t = table2(64, &quick_opts());
        assert_eq!(t.rows.len(), 3);
    }
}
