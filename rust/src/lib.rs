//! # paldx — Partitioned Local Depths at scale
//!
//! A three-layer (Rust + JAX + Pallas, AOT via XLA/PJRT) reproduction of
//! *"Sequential and Shared-Memory Parallel Algorithms for Partitioned Local
//! Depths"* (Devarakonda & Ballard, 2023).
//!
//! Given a pairwise distance matrix `D`, PaLD computes a *cohesion* matrix
//! `C` measuring the strength of pairwise relationships from relative (not
//! absolute) distances, via `O(n^3)` triplet comparisons.  This crate
//! provides:
//!
//! * the paper's two algorithmic variants — **pairwise** and **triplet** —
//!   at every rung of its optimization ladder (naive, blocked, branch-free,
//!   fully optimized), unified behind a kernel registry with a
//!   machine-model planner (`Algorithm::Auto`) and a workspace-reusing
//!   [`pald::Session`] serving API, see [`pald`];
//! * shared-memory parallel runtimes mirroring the paper's OpenMP designs:
//!   loop parallelism with reductions for pairwise, a task graph with
//!   `depend(inout)` conflict resolution for triplet, see [`parallel`];
//! * an XLA/PJRT backend executing the AOT-compiled JAX + Pallas kernels,
//!   see [`runtime`] and [`coordinator`];
//! * simulators used for the paper's analyses: an LRU cache simulator and
//!   block-traffic counters validating the communication bounds of
//!   Theorems 4.1/4.2, and a calibrated multicore machine model used to
//!   reproduce the scaling studies on this single-core testbed, see [`sim`];
//! * data substrates (synthetic distance matrices, collaboration-network
//!   graphs with BFS APSP, fastText-like word embeddings) and community
//!   analysis tools (universal strong-tie threshold, baselines), see
//!   [`data`] and [`analysis`].
//!
//! ## Quickstart
//!
//! ```no_run
//! use paldx::pald::{compute_cohesion, Algorithm, PaldConfig, Session};
//! use paldx::data::distmat;
//!
//! let d = distmat::random_tie_free(256, 42);
//! let c = compute_cohesion(&d, &PaldConfig::default()).unwrap();
//! let ties = paldx::analysis::strong_ties(&c);
//! println!("strong ties: {}", ties.len());
//!
//! // Serving pattern: planner-selected kernel, zero steady-state allocation.
//! let cfg = PaldConfig { algorithm: Algorithm::Auto, ..Default::default() };
//! let mut session = Session::new(cfg).unwrap();
//! for seed in 0..3 {
//!     let d = distmat::random_tie_free(256, seed);
//!     let c = session.compute(&d).unwrap();
//!     println!("batch item: {} ties", paldx::analysis::strong_ties(&c).len());
//! }
//! ```

pub mod analysis;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod core;
pub mod data;
pub mod io;
pub mod pald;
pub mod parallel;
pub mod repro;
pub mod runtime;
pub mod sim;
pub mod testutil;
