//! # paldx — Partitioned Local Depths at scale
//!
//! A three-layer (Rust + JAX + Pallas, AOT via XLA/PJRT) reproduction of
//! *"Sequential and Shared-Memory Parallel Algorithms for Partitioned Local
//! Depths"* (Devarakonda & Ballard, 2023).
//!
//! Given a pairwise distance matrix `D`, PaLD computes a *cohesion* matrix
//! `C` measuring the strength of pairwise relationships from relative (not
//! absolute) distances, via `O(n^3)` triplet comparisons.  This crate
//! provides:
//!
//! * the paper's two algorithmic variants — **pairwise** and **triplet** —
//!   at every rung of its optimization ladder (naive, blocked, branch-free,
//!   fully optimized), unified behind a kernel registry with a
//!   machine-model planner (`Algorithm::Auto`), a workspace-reusing
//!   [`pald::Session`] serving engine, and the typed [`pald::Pald`]
//!   facade (builder config, [`pald::DistanceInput`] inputs,
//!   [`pald::CohesionResult`] outputs, [`pald::PaldError`] errors), see
//!   [`pald`];
//! * shared-memory parallel runtimes mirroring the paper's OpenMP designs:
//!   loop parallelism with reductions for pairwise, a task graph with
//!   `depend(inout)` conflict resolution for triplet, see [`parallel`];
//! * an XLA/PJRT backend executing the AOT-compiled JAX + Pallas kernels,
//!   see [`runtime`] and [`coordinator`];
//! * an **incremental engine** ([`pald::IncrementalPald`]) maintaining
//!   cohesion across online point insertions and removals without the
//!   Θ(n³) batch recompute, with allocation-free steady-state updates,
//!   batched inserts sharing one membership scan, and re-anchor
//!   policies for long streams (DESIGN.md §8), see [`pald::incremental`]
//!   and `paldx stream`;
//! * a **sparse PKNN engine** truncating the conflict pairs to an exact
//!   symmetrized k-nearest-neighbor graph at O(n·k²) — six `knn-*`
//!   kernels in the same registry (reference, optimized, and
//!   shared-memory parallel rungs; the `knn-par-*` pair partitions the
//!   CSR edge range across threads at O(n·k²/p) while staying
//!   bit-identical to the sequential sparse kernels at every thread
//!   count), bit-identical to dense at `k = n-1` (DESIGN.md §9–§10),
//!   see [`pald::knn`] and `paldx knn`;
//! * simulators used for the paper's analyses: an LRU cache simulator and
//!   block-traffic counters validating the communication bounds of
//!   Theorems 4.1/4.2, and a calibrated multicore machine model used to
//!   reproduce the scaling studies on this single-core testbed, see [`sim`];
//! * data substrates (synthetic distance matrices, collaboration-network
//!   graphs with BFS APSP, fastText-like word embeddings) and community
//!   analysis tools (universal strong-tie threshold, baselines), see
//!   [`data`] and [`analysis`];
//! * a **serving layer** (`paldx serve`): a length-prefixed TCP protocol
//!   with admission control (bounded queue, deadlines, retriable
//!   load-shedding), a shape-keyed warm-session pool that coalesces
//!   same-shape one-shots into batched computes (bit-identical to
//!   serving them individually), wire-addressable streaming incremental
//!   sessions, graceful drain on SIGINT/SIGTERM, and a load generator
//!   (`paldx loadgen`) reporting p50/p95/p99 latency (DESIGN.md §12),
//!   see [`serve`];
//! * a **scale-out front-tier** (`paldx router`): shards traffic across
//!   `pald-serve` backends over the same wire protocol — least-inflight
//!   balancing for idempotent one-shots with transparent cross-backend
//!   retries, session-id affinity pinning each streaming session to
//!   exactly one shard (a dead shard surfaces as the typed
//!   `BackendLost`, never a silent replay), STATS-probe health checks
//!   driving a consecutive-failure circuit breaker with half-open
//!   recovery, and a `GET /metrics` scrape merging router counters with
//!   a relabeled per-backend fleet scrape (DESIGN.md §14), see
//!   [`router`].
//!
//! ## Quickstart
//!
//! The typed front door is the [`pald::Pald`] facade: a builder with
//! typed options validated at build time, any [`pald::DistanceInput`]
//! (dense, condensed, or computed on the fly from points), and a
//! [`pald::CohesionResult`] carrying the resolved plan, phase times, and
//! lazy analysis accessors.  Errors are [`pald::PaldError`] variants,
//! not strings.  (This example runs as a doctest: `cargo test --doc`.)
//!
//! ```
//! use paldx::data::distmat;
//! use paldx::pald::{
//!     Algorithm, ComputedDistances, CondensedMatrix, Metric, Pald, PaldError, Threads,
//! };
//!
//! fn main() -> Result<(), PaldError> {
//!     // Typed configuration, validated at build time.
//!     let mut pald = Pald::builder()
//!         .algorithm(Algorithm::Auto)      // planner-selected kernel
//!         .threads(Threads::Fixed(4))
//!         .build()?;
//!
//!     // Dense input (strict O(n²) validation runs by default).
//!     let d = distmat::random_tie_free(128, 42);
//!     let result = pald.compute(&d)?;
//!     println!("plan: {}", result.plan().describe());
//!     println!(
//!         "tau={:.5}, {} strong ties, {} communities, {:.3}s",
//!         result.universal_threshold(),
//!         result.strong_ties().len(),
//!         result.community_count(),
//!         result.times().total_s,
//!     );
//!
//!     // Condensed input: half the input memory, bit-identical cohesion.
//!     let condensed = CondensedMatrix::from_dense(&d)?;
//!     let again = pald.compute(&condensed)?;
//!     assert_eq!(again.cohesion().as_slice(), result.cohesion().as_slice());
//!
//!     // On-the-fly input: points + a metric, no stored distance matrix.
//!     let pts = distmat::gaussian_clusters(16, &[40, 25], &[0.2, 0.8], 12.0, 7);
//!     let computed = ComputedDistances::new(pts, Metric::Euclidean)?;
//!     println!("{} ties", pald.compute(&computed)?.strong_ties().len());
//!     Ok(())
//! }
//! ```
//!
//! ## Online serving
//!
//! When points arrive and leave continuously, convert the facade into an
//! incremental engine: each update costs the O(n²) triplets touching the
//! changed point (plus a data-dependent reweight sweep) instead of a
//! full recompute, and steady-state updates allocate nothing.
//!
//! ```
//! use paldx::data::distmat;
//! use paldx::pald::{Pald, PaldError};
//!
//! fn main() -> Result<(), PaldError> {
//!     let master = distmat::random_tie_free(64, 9);
//!     let mut eng = Pald::builder().build()?.into_incremental(&master.slice_to(60, 60))?;
//!     for q in 60..64 {
//!         eng.insert_row(&master.row(q)[..q])?; // distances to current points
//!     }
//!     eng.remove(0)?;
//!     let c = eng.cohesion(); // matches a batch recompute (oracle-tested)
//!     assert_eq!(c.rows(), 63);
//!     assert_eq!(eng.stats().grow_events, 0); // no per-update allocation
//!     Ok(())
//! }
//! ```
//!
//! The pre-0.3 free functions (`pald::compute_cohesion` & friends) still
//! work but are `#[deprecated]`; each deprecation note names the typed
//! replacement.

#![warn(missing_docs)]

pub mod analysis;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod core;
pub mod data;
pub mod io;
pub mod pald;
pub mod parallel;
pub mod repro;
pub mod router;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod testutil;
