//! paldx CLI entrypoint (full subcommand surface wired in cli/).
fn main() -> anyhow::Result<()> {
    paldx::cli::run(std::env::args().skip(1).collect())
}
