//! Property-testing driver (proptest is unavailable offline): runs a
//! property over many seeded random cases and reports the first failing
//! seed so failures reproduce exactly.  The registry-wide
//! kernel-conformance battery lives in [`conformance`].

pub mod conformance;

use crate::core::Mat;
use crate::data::distmat;
use crate::data::prng::Rng;

/// Run `prop(seed, case_index)` for `cases` deterministic seeds derived
/// from `master_seed`; panics with the failing seed on first error.
pub fn check_cases(master_seed: u64, cases: usize, prop: impl Fn(u64, usize) -> Result<(), String>) {
    let mut rng = Rng::new(master_seed);
    for i in 0..cases {
        let seed = rng.next_u64();
        if let Err(msg) = prop(seed, i) {
            panic!("property failed at case {i} (seed {seed:#x}): {msg}");
        }
    }
}

/// Random problem size in `[lo, hi]` from a seed (log-uniform-ish).
pub fn random_size(seed: u64, lo: usize, hi: usize) -> usize {
    let mut rng = Rng::new(seed ^ 0xABCD);
    lo + rng.below(hi - lo + 1)
}

/// Random tie-free distance matrix with size drawn from the seed.
pub fn random_problem(seed: u64, lo: usize, hi: usize) -> Mat {
    distmat::random_tie_free(random_size(seed, lo, hi), seed)
}

/// Assert helper returning Result for use in properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Relative-tolerance matrix comparison for properties.
pub fn matrices_close(a: &Mat, b: &Mat, rtol: f32, atol: f32) -> Result<(), String> {
    ensure(
        a.allclose(b, rtol, atol),
        format!("matrices differ: maxdiff={}", a.max_abs_diff(b)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_cases_passes_good_property() {
        check_cases(1, 20, |_seed, _i| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_cases_reports_failing_seed() {
        check_cases(1, 20, |seed, _| ensure(seed % 3 != 0, "divisible by 3"));
    }

    #[test]
    fn random_sizes_within_bounds() {
        for s in 0..100u64 {
            let n = random_size(s, 4, 40);
            assert!((4..=40).contains(&n));
        }
    }
}
