//! Registry-wide kernel-conformance harness (DESIGN.md §10).
//!
//! One data-driven battery replaces the per-suite comparison loops that
//! used to be copy-pasted across `tests/engine.rs` / `tests/knn.rs` /
//! `tests/ties.rs`: every kernel in the
//! [`REGISTRY`](crate::pald::REGISTRY) runs against the naive-pairwise
//! reference over a matrix battery (random tie-free, duplicated points
//! under both [`TieMode`]s, clustered embeddings; n ∈ {2, 3, 5, 17,
//! 64}), sparse-capable kernels additionally at k ∈ {1, n/4, n−1},
//! asserting
//!
//! * **C** within the crate's documented cross-kernel tolerance
//!   ([`RTOL`]/[`ATOL`]) of the dense reference for dense kernels, and
//!   **bit-identical** to the graph oracle
//!   ([`cohesion_over_graph`](crate::pald::knn::cohesion_over_graph))
//!   for every sparse kernel at every k (bit-identical to the dense
//!   reference at k = n−1 — the exactness anchor);
//! * **U bit-exact**: integer focus sizes recomputed by an independent
//!   O(n³) sweep match the sparse oracle on every graph edge.
//!
//! Duplicated points under `TieMode::Strict` are *undefined* semantics
//! by design (the masked rungs hit the 0·∞ caveat), so those battery
//! cases assert run-to-run bit-stability and the mutual agreement of
//! the branchy sparse orderings instead of reference agreement.
//!
//! The same battery also drives the incremental engine's update-kernel
//! registry ([`UPDATE_KERNELS`](crate::pald::UPDATE_KERNELS)) via
//! [`check_update_kernel_conformance`]: per-pair focus counts bit-exact
//! against an independent O(n) sweep, per-pair award sums bit-identical
//! across flavors, tilings, and range splits wherever the pair weight
//! is finite (the strict-mode duplicate-pair `w = ∞` caveat mirrors the
//! batch kernels' undefined case and is pinned to bit-stability only).
//!
//! The thread budgets the battery runs at come from the
//! `PALD_TEST_THREADS` environment variable (comma-separated, e.g.
//! `PALD_TEST_THREADS=1,2,4,8` — the CI thread-matrix job), defaulting
//! to `1,2,4`.  The backend axis (DESIGN.md §13) is checked by
//! [`check_backend_conformance`]: the explicit-SIMD rungs against their
//! scalar twins (U integer-exact, C within [`RTOL`]/[`ATOL`],
//! bit-identical across repeats on a reused workspace — the fixed
//! lane-reduction contract), plus the planner's resolution for every
//! backend in the `PALD_TEST_BACKEND` environment variable (the CI
//! backend-matrix job; default `auto,scalar,simd`, and an explicit
//! `simd` entry is valid on every host via the portable fallback, so
//! there are no skips anywhere).
//!
//! The third matrix axis is cohesion semantics (DESIGN.md §15):
//! [`check_semantics_conformance`] runs every registry kernel under
//! every entry of the `PALD_TEST_SEMANTICS` environment variable
//! (default `classic,weighted,rank`, mirroring the thread/backend
//! axes) against the all-semantics naive oracle
//! ([`naive::pairwise_sem`]) for dense kernels and the truncated
//! semantics oracle ([`support_over_graph_sem`]) bit-exactly for
//! sparse kernels, and pins the hook itself: rank-based is classic
//! arithmetic under forced split membership, so the two must agree
//! **bit for bit** on every rung — the proof that threading the
//! semantics hook did not perturb a single classic bit.

use crate::core::Mat;
use crate::data::distmat;
use crate::pald::knn::{
    cohesion_over_graph, focus_sizes_over_graph, support_over_graph_sem, NeighborGraph,
};
use crate::pald::{
    in_focus, naive, normalize, simd, Algorithm, Backend, CohesionKernel, CohesionSemantics,
    ExecParams, PaldConfig, Planner, TieMode, UpdateKernel, Workspace, REGISTRY, UPDATE_KERNELS,
};

/// Documented cross-kernel relative cohesion tolerance (f32 summation
/// order differs between kernels; support units themselves are exact).
pub const RTOL: f32 = 1e-4;
/// Documented cross-kernel absolute cohesion tolerance.
pub const ATOL: f32 = 1e-5;

/// How a battery case may be checked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CaseMode {
    /// Well-defined semantics: every kernel must agree with the
    /// reference (tolerance for dense, bit-exact for sparse-vs-oracle).
    Full,
    /// Exact ties under `TieMode::Strict` — undefined semantics: assert
    /// run-to-run bit-stability and branchy-sparse mutual agreement
    /// only.
    TieUndefined,
}

/// One battery entry: a distance matrix, the tie mode to run it under,
/// and how strictly it can be checked.
pub struct Case {
    /// Human-readable label used in assertion messages.
    pub name: String,
    /// The distance matrix.
    pub d: Mat,
    /// Tie handling for this case.
    pub tie: TieMode,
    /// Checking mode.
    pub mode: CaseMode,
}

/// The conformance battery: random tie-free matrices under both tie
/// modes, duplicated points under both tie modes (strict is the
/// undefined-semantics case), and clustered Euclidean embeddings, at
/// n ∈ {2, 3, 5, 17, 64}.
pub fn battery() -> Vec<Case> {
    let mut cases = Vec::new();
    for (i, &n) in [2usize, 3, 5, 17, 64].iter().enumerate() {
        let seed = 9000 + i as u64;
        cases.push(Case {
            name: format!("tie-free/strict/n={n}"),
            d: distmat::random_tie_free(n, seed),
            tie: TieMode::Strict,
            mode: CaseMode::Full,
        });
        cases.push(Case {
            name: format!("tie-free/split/n={n}"),
            d: distmat::random_tie_free(n, seed + 100),
            tie: TieMode::Split,
            mode: CaseMode::Full,
        });
        let distinct = if n < 5 { 2 } else { 3 };
        cases.push(Case {
            name: format!("duplicated/split/n={n}"),
            d: distmat::random_duplicated(n, seed + 200, distinct),
            tie: TieMode::Split,
            mode: CaseMode::Full,
        });
        cases.push(Case {
            name: format!("duplicated/strict/n={n}"),
            d: distmat::random_duplicated(n, seed + 300, distinct),
            tie: TieMode::Strict,
            mode: CaseMode::TieUndefined,
        });
    }
    for (sizes, seed) in [(&[5usize, 6, 6][..], 77u64), (&[21usize, 21, 22][..], 78)] {
        let n: usize = sizes.iter().sum();
        let pts = distmat::gaussian_clusters(4, sizes, &[0.3, 0.3, 0.3], 8.0, seed);
        cases.push(Case {
            name: format!("clustered/strict/n={n}"),
            d: distmat::euclidean(&pts),
            tie: TieMode::Strict,
            mode: CaseMode::Full,
        });
    }
    cases
}

/// Neighborhood sizes a sparse-capable kernel is checked at for an
/// `n`-point case: {1, n/4, n−1}, clamped and deduplicated.
pub fn sparse_ks(n: usize) -> Vec<usize> {
    let mut ks: Vec<usize> =
        [1usize, n / 4, n - 1].iter().map(|&k| k.clamp(1, n - 1)).collect();
    ks.sort_unstable();
    ks.dedup();
    ks
}

/// Thread budgets for the conformance/determinism suites: the
/// comma-separated `PALD_TEST_THREADS` environment variable (the CI
/// thread-matrix job sets it), defaulting to `1,2,4` when unset.
///
/// A set-but-invalid variable **panics** instead of silently falling
/// back — a misconfigured matrix must not go green while claiming
/// coverage it never ran.
pub fn test_threads() -> Vec<usize> {
    let Ok(spec) = std::env::var("PALD_TEST_THREADS") else {
        return vec![1, 2, 4];
    };
    spec.split(',')
        .map(|entry| match entry.trim().parse::<usize>() {
            Ok(t) if (1..=64).contains(&t) => t,
            _ => panic!(
                "PALD_TEST_THREADS: bad entry {entry:?} in {spec:?} \
                 (want comma-separated thread counts in 1..=64)"
            ),
        })
        .collect()
}

/// Backends the conformance battery resolves plans under: the
/// comma-separated `PALD_TEST_BACKEND` environment variable (the CI
/// backend-matrix job sets it, mirroring `PALD_TEST_THREADS`),
/// defaulting to `auto,scalar,simd` when unset — every native backend,
/// on every host: an explicit `simd` pin runs the portable 8-lane
/// fallback where AVX2 is missing, and `auto` resolves to scalar there,
/// so no entry is ever skipped.
///
/// Like [`test_threads`], a set-but-invalid variable **panics** (`xla`
/// is also rejected: the coordinator backend has no in-process kernels
/// for the battery to run).
pub fn test_backends() -> Vec<Backend> {
    let Ok(spec) = std::env::var("PALD_TEST_BACKEND") else {
        return vec![Backend::Auto, Backend::CpuScalar, Backend::CpuSimd];
    };
    spec.split(',')
        .map(|entry| match Backend::parse(entry.trim()) {
            Some(Backend::Xla) | None => panic!(
                "PALD_TEST_BACKEND: bad entry {entry:?} in {spec:?} \
                 (want comma-separated names from auto|scalar|simd)"
            ),
            Some(b) => b,
        })
        .collect()
}

/// Cohesion-semantics axes the battery runs under: the comma-separated
/// `PALD_TEST_SEMANTICS` environment variable (the CI semantics-matrix
/// job sets it, mirroring `PALD_TEST_THREADS` / `PALD_TEST_BACKEND`),
/// defaulting to `classic,weighted,rank` — every semantics, on every
/// host, no skips.  Like the other axes, a set-but-invalid variable
/// **panics** instead of silently falling back.
pub fn test_semantics() -> Vec<CohesionSemantics> {
    let Ok(spec) = std::env::var("PALD_TEST_SEMANTICS") else {
        return vec![
            CohesionSemantics::Classic,
            CohesionSemantics::DistanceWeighted,
            CohesionSemantics::RankBased,
        ];
    };
    spec.split(',')
        .map(|entry| match CohesionSemantics::parse(entry.trim()) {
            Ok(sem) => sem,
            Err(_) => panic!(
                "PALD_TEST_SEMANTICS: bad entry {entry:?} in {spec:?} \
                 (want comma-separated names from classic|rank|weighted)"
            ),
        })
        .collect()
}

/// Run one registered kernel through the trait path (compute_into +
/// normalization) with the battery's block sizes, classic semantics.
fn run_kernel(
    kernel: &dyn CohesionKernel,
    d: &Mat,
    tie: TieMode,
    threads: usize,
    k: usize,
    ws: &mut Workspace,
) -> Mat {
    run_kernel_sem(kernel, d, tie, CohesionSemantics::Classic, threads, k, ws)
}

/// [`run_kernel`] under an explicit [`CohesionSemantics`].
#[allow(clippy::too_many_arguments)]
fn run_kernel_sem(
    kernel: &dyn CohesionKernel,
    d: &Mat,
    tie: TieMode,
    sem: CohesionSemantics,
    threads: usize,
    k: usize,
    ws: &mut Workspace,
) -> Mat {
    let n = d.rows();
    let p = ExecParams {
        tie,
        semantics: sem,
        block: 8,
        block2: 4,
        threads,
        k,
        backend: Backend::Auto,
    };
    let mut c = Mat::zeros(n, n);
    kernel.compute_into(d, &p, ws, &mut c);
    normalize(&mut c);
    c
}

/// Bit-level matrix equality (NaN-safe: compares the f32 bit patterns,
/// so deterministic NaNs from the strict-tie 0·∞ caveat still compare
/// equal across runs).
fn assert_bits_eq(a: &Mat, b: &Mat, ctx: &str) {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{ctx}: shape");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "{ctx}: bit mismatch at flat index {i}: {x} vs {y}"
        );
    }
}

/// Bit-level f64 slice equality (NaN-safe, like [`assert_bits_eq`]).
fn assert_f64_bits_eq(a: &[f64], b: &[f64], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{ctx}: bit mismatch at index {i}: {x} vs {y}");
    }
}

/// Independent O(n³) dense focus-size reference: `U[x][y]` counts every
/// z with `in_focus` over the complete candidate set.
fn naive_focus_sizes(d: &Mat, tie: TieMode) -> Mat {
    let n = d.rows();
    let mut u = Mat::zeros(n, n);
    for x in 0..n {
        for y in (x + 1)..n {
            let dxy = d[(x, y)];
            let cnt = (0..n)
                .filter(|&z| in_focus(d[(x, z)], d[(y, z)], dxy, tie))
                .count() as f32;
            u[(x, y)] = cnt;
            u[(y, x)] = cnt;
        }
    }
    u
}

/// Independent truncated focus-size reference: counts candidates via
/// per-z graph membership (`z ∈ N(x) ∪ N(y)` iff `contains(x,z) ||
/// contains(y,z)`; symmetrization puts x and y themselves in the set) —
/// a different formulation than the kernels' sorted-list merges, so a
/// bit-exact match is a real cross-check.
fn truncated_focus_reference(d: &Mat, g: &NeighborGraph, tie: TieMode) -> Mat {
    let n = d.rows();
    let mut u = Mat::zeros(n, n);
    for x in 0..n {
        for y in (x + 1)..n {
            if !g.contains(x, y) {
                continue;
            }
            let dxy = d[(x, y)];
            let cnt = (0..n)
                .filter(|&z| {
                    (g.contains(x, z) || g.contains(y, z))
                        && in_focus(d[(x, z)], d[(y, z)], dxy, tie)
                })
                .count() as f32;
            u[(x, y)] = cnt;
            u[(y, x)] = cnt;
        }
    }
    u
}

/// Every registered kernel agrees with the naive-pairwise reference on
/// one matrix within the documented tolerance (sparse kernels run at
/// the complete-graph fallback `k = 0`).  The shared inner loop of the
/// seeded property suites in `tests/ties.rs` / `tests/properties.rs`;
/// `ctx` (e.g. the case seed) is prepended to assertion messages so
/// seeded failures stay reproducible.
pub fn assert_registry_matches_reference(d: &Mat, tie: TieMode, threads: usize, ctx: &str) {
    let reference = naive::pairwise(d, tie);
    let mut ws = Workspace::new();
    for kernel in REGISTRY {
        let c = run_kernel(kernel, d, tie, threads, 0, &mut ws);
        assert!(
            c.allclose(&reference, RTOL, ATOL),
            "{ctx}: {} (n={}, {tie:?}, p={threads}): maxdiff={}",
            kernel.name(),
            d.rows(),
            c.max_abs_diff(&reference)
        );
    }
}

/// The full conformance pass at one thread budget: every battery case ×
/// every registry kernel (× every `sparse_ks` size for sparse kernels),
/// with the C and U assertions described in the module docs.
pub fn check_kernel_conformance(threads: usize) {
    let mut ws = Workspace::new();
    for case in battery() {
        let d = &case.d;
        let n = d.rows();
        let ctx_base = format!("{} p={threads}", case.name);
        if case.mode == CaseMode::TieUndefined {
            // Undefined semantics: every kernel must still be
            // run-to-run bit-stable (except the dense parallel triplet,
            // whose task order is documented as run-dependent), and the
            // two branchy sparse orderings must agree bit-for-bit.
            for kernel in REGISTRY {
                if kernel.algorithm() == Algorithm::ParallelTriplet {
                    continue;
                }
                let k = if kernel.meta().sparse { n - 1 } else { 0 };
                let a = run_kernel(kernel, d, case.tie, threads, k, &mut ws);
                let b = run_kernel(kernel, d, case.tie, threads, k, &mut ws);
                assert_bits_eq(&a, &b, &format!("{ctx_base} {} repeat", kernel.name()));
            }
            for k in sparse_ks(n) {
                let a = run_kernel(
                    Algorithm::KnnPairwise.kernel().unwrap(),
                    d,
                    case.tie,
                    threads,
                    k,
                    &mut ws,
                );
                let b = run_kernel(
                    Algorithm::KnnTriplet.kernel().unwrap(),
                    d,
                    case.tie,
                    threads,
                    k,
                    &mut ws,
                );
                assert_bits_eq(&a, &b, &format!("{ctx_base} knn reference orderings k={k}"));
            }
            continue;
        }

        let cref = naive::pairwise(d, case.tie);
        let uref = naive_focus_sizes(d, case.tie);
        // Dense kernels: tolerance agreement with the reference.
        for kernel in REGISTRY.iter().filter(|k| !k.meta().sparse) {
            let c = run_kernel(*kernel, d, case.tie, threads, 0, &mut ws);
            assert!(
                c.allclose(&cref, RTOL, ATOL),
                "{ctx_base} {}: maxdiff={}",
                kernel.name(),
                c.max_abs_diff(&cref)
            );
        }
        // Sparse kernels: bit-exact against the graph oracle at every
        // k, bit-exact against the dense reference at k = n-1; focus
        // sizes integer-exact against an independent reference.
        for k in sparse_ks(n) {
            let g = NeighborGraph::build(d, k).expect("battery k is valid");
            let oracle = cohesion_over_graph(d, &g, case.tie);
            let ug = focus_sizes_over_graph(d, &g, case.tie);
            let uind = truncated_focus_reference(d, &g, case.tie);
            assert_eq!(
                ug.as_slice(),
                uind.as_slice(),
                "{ctx_base} k={k}: truncated U not integer-exact"
            );
            if k == n - 1 {
                assert_eq!(
                    ug.as_slice(),
                    uref.as_slice(),
                    "{ctx_base}: complete-graph U must equal the dense U"
                );
            }
            for kernel in REGISTRY.iter().filter(|k| k.meta().sparse) {
                let c = run_kernel(*kernel, d, case.tie, threads, k, &mut ws);
                assert_eq!(
                    c.as_slice(),
                    oracle.as_slice(),
                    "{ctx_base} {} k={k}: sparse kernel diverged from the graph oracle",
                    kernel.name()
                );
                if k == n - 1 {
                    assert_eq!(
                        c.as_slice(),
                        cref.as_slice(),
                        "{ctx_base} {}: k=n-1 must be bit-identical to dense",
                        kernel.name()
                    );
                }
            }
        }
    }
}

/// The cross-backend oracle (DESIGN.md §13): the explicit-SIMD rungs
/// checked against their scalar twins on every battery case, plus the
/// planner's backend resolution for every backend in [`test_backends`].
///
/// * **U integer-exact**: the SIMD focus-size pass and the per-pair
///   SIMD focus counter reproduce the independent O(n³) dense sweep
///   bit-for-bit — focus sizes are small integer counts, so the fixed
///   lane-reduction order cannot change them in *any* order;
/// * **C within the documented tolerance** ([`RTOL`]/[`ATOL`]) of the
///   scalar twin for the dense SIMD rungs (f32 summation order differs
///   by lane grouping, like any other rung pair), and **bit-identical**
///   for `knn-simd-pairwise` at every battery k — only the integer
///   count path vectorizes; the sparse award order is shared with the
///   masked scalar rung;
/// * **bit-identical across repeats on a reused [`Workspace`]** — the
///   fixed lane-reduction determinism contract, on AVX2 and portable
///   hosts alike;
/// * for every backend in `PALD_TEST_BACKEND`, the planner resolves
///   `Algorithm::Auto` to a kernel *on that backend* (`auto` resolves
///   to scalar on non-AVX2 hosts — checked, never skipped) and the
///   resolved plan reproduces the naive reference within tolerance.
pub fn check_backend_conformance(threads: usize) {
    let mut ws = Workspace::new();
    let backends = test_backends();
    let simd_algs =
        [Algorithm::SimdPairwise, Algorithm::SimdTriplet, Algorithm::KnnSimdPairwise];
    for case in battery() {
        let d = &case.d;
        let n = d.rows();
        let ctx = format!("{} p={threads}", case.name);
        if case.mode == CaseMode::TieUndefined {
            // Undefined semantics: the SIMD rungs must still be
            // run-to-run bit-stable on the reused workspace.
            for alg in simd_algs {
                let kernel = alg.kernel().unwrap();
                let k = if kernel.meta().sparse { n - 1 } else { 0 };
                let a = run_kernel(kernel, d, case.tie, threads, k, &mut ws);
                let b = run_kernel(kernel, d, case.tie, threads, k, &mut ws);
                assert_bits_eq(&a, &b, &format!("{ctx} {} repeat", kernel.name()));
            }
            continue;
        }

        // U: the SIMD focus-size pass and the per-pair counter are
        // integer-exact against the independent dense sweep.
        let uref = naive_focus_sizes(d, case.tie);
        let mut u = Mat::zeros(n, n);
        simd::focus_sizes_simd_into(d, case.tie, 8, &mut u);
        assert_eq!(
            u.as_slice(),
            uref.as_slice(),
            "{ctx}: simd focus sizes not integer-exact"
        );
        for x in 0..n {
            for y in (x + 1)..n {
                assert_eq!(
                    simd::count_focus_simd(d.row(x), d.row(y), d[(x, y)], case.tie),
                    uref[(x, y)] as u32,
                    "{ctx}: count_focus_simd({x},{y}) diverged from the sweep"
                );
            }
        }

        // Dense SIMD rungs vs their scalar twins: tolerance C, bitwise
        // repeatability.
        for (scalar, vec_alg) in [
            (Algorithm::OptimizedPairwise, Algorithm::SimdPairwise),
            (Algorithm::OptimizedTriplet, Algorithm::SimdTriplet),
        ] {
            let want = run_kernel(scalar.kernel().unwrap(), d, case.tie, threads, 0, &mut ws);
            let kernel = vec_alg.kernel().unwrap();
            let a = run_kernel(kernel, d, case.tie, threads, 0, &mut ws);
            assert!(
                a.allclose(&want, RTOL, ATOL),
                "{ctx} {} vs {}: maxdiff={}",
                kernel.name(),
                scalar.name(),
                a.max_abs_diff(&want)
            );
            let b = run_kernel(kernel, d, case.tie, threads, 0, &mut ws);
            assert_bits_eq(&a, &b, &format!("{ctx} {} repeat", kernel.name()));
        }

        // Sparse SIMD rung: bit-identical to the masked scalar rung at
        // every battery k.
        for k in sparse_ks(n) {
            let want = run_kernel(
                Algorithm::KnnOptPairwise.kernel().unwrap(),
                d,
                case.tie,
                threads,
                k,
                &mut ws,
            );
            let a = run_kernel(
                Algorithm::KnnSimdPairwise.kernel().unwrap(),
                d,
                case.tie,
                threads,
                k,
                &mut ws,
            );
            assert_eq!(
                a.as_slice(),
                want.as_slice(),
                "{ctx} k={k}: knn-simd-pairwise not bit-identical to knn-opt-pairwise"
            );
        }

        // Planner resolution per requested backend.
        let cref = naive::pairwise(d, case.tie);
        for &backend in &backends {
            let cfg = PaldConfig {
                algorithm: Algorithm::Auto,
                tie_mode: case.tie,
                threads,
                backend,
                ..Default::default()
            };
            let plan = Planner::new().resolve(&cfg, n);
            match backend {
                Backend::CpuScalar => assert_eq!(
                    plan.backend,
                    Backend::CpuScalar,
                    "{ctx}: scalar pin leaked off-backend: {}",
                    plan.describe()
                ),
                Backend::CpuSimd => assert_eq!(
                    plan.backend,
                    Backend::CpuSimd,
                    "{ctx}: simd pin leaked off-backend: {}",
                    plan.describe()
                ),
                Backend::Auto => assert!(
                    plan.backend == Backend::CpuScalar || plan.backend == Backend::CpuSimd,
                    "{ctx}: auto resolved to an unresolved backend: {}",
                    plan.describe()
                ),
                Backend::Xla => unreachable!("test_backends never yields xla"),
            }
            let kernel = plan.algorithm.kernel().unwrap();
            let c =
                run_kernel(kernel, d, case.tie, plan.params.threads, plan.params.k, &mut ws);
            assert!(
                c.allclose(&cref, RTOL, ATOL),
                "{ctx} backend={} resolved {}: maxdiff={}",
                backend.name(),
                plan.algorithm.name(),
                c.max_abs_diff(&cref)
            );
        }
    }
}

/// Run one update-kernel flavor over a pair's full z-range with the
/// given tiling and return the two award-sum vectors.
#[allow(clippy::too_many_arguments)]
fn run_update_kernel(
    kernel: &dyn UpdateKernel,
    dx: &[f32],
    dy: &[f32],
    dxy: f32,
    w: f64,
    block: usize,
    split: Option<usize>,
    tie: TieMode,
    sem: CohesionSemantics,
) -> (Vec<f64>, Vec<f64>) {
    let n = dx.len();
    let mut sx = vec![0.0f64; n];
    let mut sy = vec![0.0f64; n];
    match split {
        None => kernel.award(dx, dy, dxy, w, &mut sx, &mut sy, 0, n, block, tie, sem),
        Some(mid) => {
            kernel.award(dx, dy, dxy, w, &mut sx, &mut sy, 0, mid, block, tie, sem);
            kernel.award(dx, dy, dxy, w, &mut sx, &mut sy, mid, n, block, tie, sem);
        }
    }
    (sx, sy)
}

/// Conformance battery for the incremental update-kernel registry
/// (DESIGN.md §8): both registered flavors (`reference`,
/// `blocked-branchfree`) run over every pair of every batch-battery
/// case, asserting
///
/// * **focus counts bit-exact**: every flavor's `count_focus` matches
///   the independent O(n³) dense sweep ([`naive_focus_sizes`]) on every
///   pair — including the strict-mode duplicate cases, where the count
///   itself stays well-defined;
/// * **award sums bit-identical across flavors** wherever the pair
///   weight `w = 1/u_xy` is finite (the trait's documented contract:
///   masks multiply `w` by exactly 0, 0.5, or 1), and invariant under
///   tiling (`block` ∈ {1, 3, 8, n}) and z-range splitting;
/// * the strict-mode duplicate pairs with `u_xy = 0` (so `w = ∞`) are
///   the update twin of the batch kernels' 0·∞ caveat: the branchy
///   reference must leave the sums untouched and the masked flavor must
///   be run-to-run bit-stable (its NaNs are deterministic).
pub fn check_update_kernel_conformance() {
    for case in battery() {
        let d = &case.d;
        let n = d.rows();
        let uref = naive_focus_sizes(d, case.tie);
        for x in 0..n {
            for y in (x + 1)..n {
                let (dx, dy) = (d.row(x), d.row(y));
                let dxy = d[(x, y)];
                let u = uref[(x, y)] as u32;
                let ctx = format!("{} pair=({x},{y})", case.name);
                for kernel in UPDATE_KERNELS {
                    assert_eq!(
                        kernel.count_focus(dx, dy, dxy, case.tie),
                        u,
                        "{ctx} {}: count_focus diverged from the independent sweep",
                        kernel.name()
                    );
                }
                let w = if u > 0 { 1.0 / f64::from(u) } else { f64::INFINITY };
                if u == 0 {
                    // Strict-mode duplicate pair: w = ∞, undefined for
                    // the masked flavor (0 · ∞ = NaN).  Reference must
                    // award nothing; masked must be bit-stable.
                    let (sx, sy) = run_update_kernel(
                        UPDATE_KERNELS[0],
                        dx,
                        dy,
                        dxy,
                        w,
                        8,
                        None,
                        case.tie,
                        CohesionSemantics::Classic,
                    );
                    assert!(
                        sx.iter().chain(&sy).all(|&v| v == 0.0),
                        "{ctx}: reference awarded support outside an empty focus"
                    );
                    let masked = UPDATE_KERNELS[1];
                    let sem = CohesionSemantics::Classic;
                    let a = run_update_kernel(masked, dx, dy, dxy, w, 8, None, case.tie, sem);
                    let b = run_update_kernel(masked, dx, dy, dxy, w, 8, None, case.tie, sem);
                    assert_f64_bits_eq(&a.0, &b.0, &format!("{ctx} masked repeat sx"));
                    assert_f64_bits_eq(&a.1, &b.1, &format!("{ctx} masked repeat sy"));
                    continue;
                }
                for sem in CohesionSemantics::ALL {
                    let want = run_update_kernel(
                        UPDATE_KERNELS[0],
                        dx,
                        dy,
                        dxy,
                        w,
                        8,
                        None,
                        case.tie,
                        sem,
                    );
                    for kernel in UPDATE_KERNELS {
                        for block in [1usize, 3, 8, n] {
                            for split in [None, Some(n / 2)] {
                                let got = run_update_kernel(
                                    kernel, dx, dy, dxy, w, block, split, case.tie, sem,
                                );
                                let kctx = format!(
                                    "{ctx} {} {} block={block} split={split:?}",
                                    kernel.name(),
                                    sem.name()
                                );
                                assert_f64_bits_eq(&got.0, &want.0, &format!("{kctx} sx"));
                                assert_f64_bits_eq(&got.1, &want.1, &format!("{kctx} sy"));
                            }
                        }
                    }
                }
            }
        }
    }
}

/// The cohesion-semantics axis of the battery (DESIGN.md §15): every
/// registry kernel under every semantics in [`test_semantics`], at one
/// thread budget, asserting
///
/// * **dense kernels within [`RTOL`]/[`ATOL`]** of the all-semantics
///   naive oracle ([`naive::pairwise_sem`]) on every well-defined
///   battery case (non-classic semantics force split membership, so
///   even the strict-tie duplicate cases are well-defined for them —
///   only classic/strict duplicates stay with the classic battery's
///   bit-stability pin);
/// * **sparse kernels bit-identical** to the truncated semantics
///   oracle ([`support_over_graph_sem`]) at every battery k;
/// * **the classic bit-identity pin**: rank-based semantics is classic
///   arithmetic under forced `<=` membership, so on every rung (modulo
///   the run-order-dependent dense parallel triplet) a rank-based run
///   must reproduce the classic split-mode run **bit for bit** — if
///   threading the hook had perturbed even one classic multiply, this
///   cross-check would see the bit flip.
pub fn check_semantics_conformance(threads: usize) {
    let mut ws = Workspace::new();
    let sems = test_semantics();
    for case in battery() {
        let d = &case.d;
        let n = d.rows();
        for &sem in &sems {
            if case.mode == CaseMode::TieUndefined && sem == CohesionSemantics::Classic {
                continue;
            }
            let ctx = format!("{} p={threads} sem={}", case.name, sem.name());
            let cref = naive::pairwise_sem(d, case.tie, sem);
            for kernel in REGISTRY.iter().filter(|k| !k.meta().sparse) {
                let c = run_kernel_sem(*kernel, d, case.tie, sem, threads, 0, &mut ws);
                assert!(
                    c.allclose(&cref, RTOL, ATOL),
                    "{ctx} {}: maxdiff={}",
                    kernel.name(),
                    c.max_abs_diff(&cref)
                );
            }
            for k in sparse_ks(n) {
                let g = NeighborGraph::build(d, k).expect("battery k is valid");
                let mut oracle = support_over_graph_sem(d, &g, case.tie, sem);
                normalize(&mut oracle);
                for kernel in REGISTRY.iter().filter(|k| k.meta().sparse) {
                    let c = run_kernel_sem(*kernel, d, case.tie, sem, threads, k, &mut ws);
                    assert_eq!(
                        c.as_slice(),
                        oracle.as_slice(),
                        "{ctx} {} k={k}: sparse kernel diverged from the semantics oracle",
                        kernel.name()
                    );
                }
            }
        }
        if case.mode == CaseMode::Full
            && sems.contains(&CohesionSemantics::Classic)
            && sems.contains(&CohesionSemantics::RankBased)
        {
            for kernel in REGISTRY {
                if kernel.algorithm() == Algorithm::ParallelTriplet {
                    continue; // documented run-dependent task order
                }
                let k = if kernel.meta().sparse { n - 1 } else { 0 };
                let a = run_kernel_sem(
                    kernel,
                    d,
                    TieMode::Split,
                    CohesionSemantics::Classic,
                    threads,
                    k,
                    &mut ws,
                );
                let b = run_kernel_sem(
                    kernel,
                    d,
                    TieMode::Split,
                    CohesionSemantics::RankBased,
                    threads,
                    k,
                    &mut ws,
                );
                assert_bits_eq(
                    &a,
                    &b,
                    &format!(
                        "{} p={threads} {}: rank-based vs classic under split",
                        case.name,
                        kernel.name()
                    ),
                );
            }
        }
    }
}

/// Determinism pins for the parallel kernels (DESIGN.md §10):
///
/// * the sparse `knn-par-*` pair is bit-identical to the sequential
///   sparse reference at **every** thread count in `threads_list`, and
///   bitwise repeatable on a reused workspace;
/// * dense `par-pairwise` and `par-hybrid` are bitwise repeatable and
///   bit-identical **across** thread counts ≥ 2 (integer focus
///   reduction + column-ownership cohesion: per-cell summation order is
///   partition-independent);
/// * dense `par-triplet` promises tolerance-level reproducibility only
///   (its task graph executes conflicting tasks in a run-dependent
///   order, like the OpenMP original).
pub fn check_parallel_determinism(threads_list: &[usize]) {
    let mut ws = Workspace::new();
    for (d, tie) in [
        (distmat::random_tie_free(41, 2029), TieMode::Strict),
        (distmat::random_duplicated(34, 2030, 3), TieMode::Split),
    ] {
        let n = d.rows();
        // Sparse parallel pair vs the sequential branchy reference.
        for alg in [Algorithm::KnnParPairwise, Algorithm::KnnParTriplet] {
            let kernel = alg.kernel().unwrap();
            for k in [3usize, 9, n - 1] {
                let want = run_kernel(
                    Algorithm::KnnPairwise.kernel().unwrap(),
                    &d,
                    tie,
                    1,
                    k,
                    &mut ws,
                );
                for &p in threads_list {
                    let got = run_kernel(kernel, &d, tie, p, k, &mut ws);
                    assert_eq!(
                        got.as_slice(),
                        want.as_slice(),
                        "{} k={k} p={p} ({tie:?}): not bit-identical to sequential",
                        kernel.name()
                    );
                    let again = run_kernel(kernel, &d, tie, p, k, &mut ws);
                    assert_eq!(
                        again.as_slice(),
                        want.as_slice(),
                        "{} k={k} p={p} ({tie:?}): workspace reuse not bitwise stable",
                        kernel.name()
                    );
                }
            }
        }
        // Dense parallel pairwise + hybrid: fixed-order reduction and
        // column ownership make them bit-identical across real thread
        // counts (p = 1 delegates to a different sequential kernel, so
        // it is excluded from the cross-count pin).
        for alg in [Algorithm::ParallelPairwise, Algorithm::ParallelHybrid] {
            let kernel = alg.kernel().unwrap();
            let mut baseline: Option<Mat> = None;
            for &p in threads_list.iter().filter(|&&p| p >= 2) {
                let a = run_kernel(kernel, &d, tie, p, 0, &mut ws);
                let b = run_kernel(kernel, &d, tie, p, 0, &mut ws);
                assert_eq!(
                    a.as_slice(),
                    b.as_slice(),
                    "{} p={p} ({tie:?}): repeat run not bitwise stable",
                    kernel.name()
                );
                match &baseline {
                    None => baseline = Some(a),
                    Some(base) => assert_eq!(
                        a.as_slice(),
                        base.as_slice(),
                        "{} p={p} ({tie:?}): thread count changed the bits",
                        kernel.name()
                    ),
                }
            }
        }
        // Dense parallel triplet: tolerance-level reproducibility only.
        let kernel = Algorithm::ParallelTriplet.kernel().unwrap();
        for &p in threads_list.iter().filter(|&&p| p >= 2) {
            let a = run_kernel(kernel, &d, tie, p, 0, &mut ws);
            let b = run_kernel(kernel, &d, tie, p, 0, &mut ws);
            assert!(
                a.allclose(&b, 1e-5, 1e-6),
                "par-triplet p={p} ({tie:?}): runs differ beyond tolerance: {}",
                a.max_abs_diff(&b)
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn battery_shapes_and_ks() {
        let cases = battery();
        assert!(cases.len() >= 20);
        assert!(cases.iter().any(|c| c.d.rows() == 2));
        assert!(cases.iter().any(|c| c.d.rows() == 64));
        assert!(cases.iter().any(|c| c.mode == CaseMode::TieUndefined));
        assert_eq!(sparse_ks(2), vec![1]);
        assert_eq!(sparse_ks(3), vec![1, 2]);
        assert_eq!(sparse_ks(17), vec![1, 4, 16]);
        assert_eq!(sparse_ks(64), vec![1, 16, 63]);
    }

    #[test]
    fn env_thread_list_parses() {
        // Not set in unit tests by default: the fallback applies.  (The
        // CI thread-matrix job exercises the env path end to end.)
        let v = test_threads();
        assert!(!v.is_empty());
        assert!(v.iter().all(|&t| t >= 1));
    }

    #[test]
    fn env_semantics_list_parses() {
        // Unset (the usual unit-test case): every semantics, no skips.
        let v = test_semantics();
        assert!(v.contains(&CohesionSemantics::Classic));
        assert!(v.contains(&CohesionSemantics::RankBased));
        assert!(v.contains(&CohesionSemantics::DistanceWeighted));
    }

    #[test]
    fn env_backend_list_parses() {
        // Unset (the usual unit-test case): every native backend, so a
        // default run covers scalar, simd, and the auto resolution with
        // no skips on any host.  (The CI backend-matrix job exercises
        // the env path end to end.)
        let v = test_backends();
        assert!(v.contains(&Backend::Auto));
        assert!(v.contains(&Backend::CpuScalar));
        assert!(v.contains(&Backend::CpuSimd));
        assert!(!v.contains(&Backend::Xla));
    }
}
