//! L3 coordinator: the deployment-facing orchestration layer.
//!
//! For a *computation* request it plans the work (algorithm + backend +
//! block sizes), dispatches to the native kernels or the XLA runtime
//! (padding to the best-fitting AOT artifact), accumulates phase metrics,
//! and post-processes (strong ties, communities) on demand.  The paper's
//! contribution is the algorithm family itself, so L3 stays a thin,
//! explicit driver (see DESIGN.md §1) — but it is the single entry point
//! the CLI, examples, and benches all go through.

mod metrics;

pub use metrics::{relabel_scrape, JobMetrics, MetricsRegistry};

use std::path::PathBuf;

use crate::core::Mat;
use crate::pald::{self, Algorithm, Backend, PaldBuilder, PaldConfig, TieMode, Validation};
use crate::runtime::XlaRuntime;

/// A cohesion-computation job.
#[derive(Clone, Debug)]
pub struct Job {
    /// Full computation configuration (algorithm, ties, blocks, backend).
    pub config: PaldConfig,
    /// Artifacts directory for the XLA backend.
    pub artifacts_dir: PathBuf,
}

impl Default for Job {
    fn default() -> Self {
        Job { config: PaldConfig::default(), artifacts_dir: PathBuf::from("artifacts") }
    }
}

/// Coordinator owning the (lazily created) XLA runtime and metrics.
pub struct Coordinator {
    xla: Option<XlaRuntime>,
    /// Accumulated per-job metrics.
    pub metrics: MetricsRegistry,
}

impl Coordinator {
    /// Coordinator with no runtime loaded yet (XLA is created lazily).
    pub fn new() -> Coordinator {
        Coordinator { xla: None, metrics: MetricsRegistry::default() }
    }

    /// Compute cohesion for `d` under `job`, recording metrics.  Metrics
    /// attribute the *resolved* kernel (never "auto"), so per-kernel
    /// timings stay meaningful under planner-selected jobs.
    pub fn run(&mut self, d: &Mat, job: &Job) -> anyhow::Result<Mat> {
        let t0 = std::time::Instant::now();
        let (algorithm, backend) = match job.config.backend {
            Backend::Xla => (job.config.algorithm.name(), Backend::Xla.name()),
            // Invalid shapes are rejected by the native compute path
            // below; skip planning for them so the error path stays
            // panic-free.
            _ if d.rows() >= 2 && d.rows() == d.cols() => {
                let plan = pald::plan_for(&job.config, d.rows());
                (plan.algorithm.name(), plan.backend.name())
            }
            _ => (job.config.algorithm.name(), job.config.backend.name()),
        };
        let c = match job.config.backend {
            Backend::Xla => self.run_xla(d, job)?,
            // Validation::Skip preserves this layer's contract: the
            // coordinator serves pre-validated jobs; strict input checks
            // belong to the caller-facing `Pald` facade.
            _ => PaldBuilder::from_config(&job.config)
                .validation(Validation::Skip)
                .build()?
                .compute(d)?
                .into_matrix(),
        };
        self.metrics.record(JobMetrics {
            n: d.rows(),
            // Truncated jobs are charged their actual O(n·k²) work by
            // JobMetrics::work_units, not the dense n³/6.
            k: job.config.k,
            algorithm: algorithm.to_string(),
            backend: backend.to_string(),
            seconds: t0.elapsed().as_secs_f64(),
        });
        Ok(c)
    }

    fn run_xla(&mut self, d: &Mat, job: &Job) -> anyhow::Result<Mat> {
        if self.xla.is_none() {
            self.xla = Some(XlaRuntime::new(&job.artifacts_dir)?);
        }
        let rt = self.xla.as_mut().expect("just initialized");
        let tie = match job.config.tie_mode {
            TieMode::Strict => "strict",
            TieMode::Split => "split",
        };
        let exe = rt.executable_for(d.rows(), tie)?;
        exe.run(d, tie == "strict")
    }

    /// Plan summary for logging: which backend/artifact a job would use.
    /// `Algorithm::Auto` is resolved through the planner so the log shows
    /// the concrete kernel + tuned block sizes that will execute.
    pub fn plan(&mut self, n: usize, job: &Job) -> anyhow::Result<String> {
        Ok(match job.config.backend {
            Backend::Xla => {
                if self.xla.is_none() {
                    self.xla = Some(XlaRuntime::new(&job.artifacts_dir)?);
                }
                let rt = self.xla.as_mut().expect("just initialized");
                let tie = match job.config.tie_mode {
                    TieMode::Strict => "strict",
                    TieMode::Split => "split",
                };
                let spec = rt
                    .manifest()
                    .best_fit(n, tie)
                    .ok_or_else(|| anyhow::anyhow!("no artifact for n={n}"))?;
                format!(
                    "xla artifact={} (n={} block={}) pad {} -> {}",
                    spec.name, spec.n, spec.block, n, spec.n
                )
            }
            _ => {
                let plan = pald::plan_for(&job.config, n);
                format!("native {}", plan.describe())
            }
        })
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

/// Convenience: pick a sensible default algorithm for problem size/threads
/// (the paper's guidance: triplet sequentially, pairwise in parallel).
pub fn default_algorithm(n: usize, threads: usize) -> Algorithm {
    if threads > 1 {
        Algorithm::ParallelPairwise
    } else if n >= 1024 {
        Algorithm::OptimizedTriplet
    } else {
        Algorithm::OptimizedPairwise
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::distmat;

    #[test]
    fn native_run_records_metrics() {
        let mut coord = Coordinator::new();
        let d = distmat::random_tie_free(24, 3);
        let c = coord.run(&d, &Job::default()).unwrap();
        assert_eq!(c.rows(), 24);
        assert_eq!(coord.metrics.jobs().len(), 1);
        assert_eq!(coord.metrics.jobs()[0].n, 24);
        // Metrics attribute the *resolved* backend of the planned kernel
        // (the default Backend::Auto never appears).
        let b = coord.metrics.jobs()[0].backend.as_str();
        assert!(b == "scalar" || b == "simd", "unresolved backend in metrics: {b}");
    }

    #[test]
    fn default_algorithm_policy() {
        assert_eq!(default_algorithm(100, 8), Algorithm::ParallelPairwise);
        assert_eq!(default_algorithm(2048, 1), Algorithm::OptimizedTriplet);
        assert_eq!(default_algorithm(100, 1), Algorithm::OptimizedPairwise);
    }

    #[test]
    fn plan_describes_native_jobs() {
        let mut coord = Coordinator::new();
        let plan = coord.plan(100, &Job::default()).unwrap();
        assert!(plan.contains("native"));
        assert!(plan.contains("algorithm="));
    }

    #[test]
    fn auto_jobs_resolve_and_run() {
        let mut coord = Coordinator::new();
        let d = distmat::random_tie_free(32, 5);
        let job = Job {
            config: PaldConfig { algorithm: Algorithm::Auto, ..Default::default() },
            ..Default::default()
        };
        let plan = coord.plan(32, &job).unwrap();
        assert!(!plan.contains("algorithm=auto"), "plan must name the concrete kernel: {plan}");
        let c = coord.run(&d, &job).unwrap();
        assert!((c.sum() - 16.0).abs() < 1e-3);
        // Metrics attribute the resolved kernel, not the Auto directive.
        assert_ne!(coord.metrics.jobs()[0].algorithm, "auto");
    }
}
