//! Job metrics registry: the throughput-accounting spine shared by the
//! e2e drivers and the serving layer (`paldx serve` exposes it via the
//! `STATS` frame and the plaintext scrape endpoint; DESIGN.md §12).
//!
//! Two properties matter here:
//!
//! * **Work-aware throughput.**  [`JobMetrics::work_units`] charges each
//!   job the comparisons it actually performed — `n³/6` triplets for a
//!   dense job, `n·k²` for a truncated PKNN job (DESIGN.md §9) — so the
//!   domain metric no longer overstates sparse throughput by pretending
//!   every job swept the full triplet space.
//! * **Thread-safe recording with snapshot semantics.**  The registry is
//!   sharded: each recording thread is pinned (round-robin, cached in a
//!   thread-local) to one shard guarded by its own `Mutex`, so worker
//!   threads on the serving hot path never contend on a global lock.
//!   Readers call [`MetricsRegistry::snapshot`], which locks shards one
//!   at a time and merges by a global sequence number — a consistent
//!   completion-ordered view without stopping writers.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Metrics of one completed job.
#[derive(Clone, Debug)]
pub struct JobMetrics {
    /// Problem size (points).
    pub n: usize,
    /// Truncated-neighborhood size of the job (`0` = dense semantics:
    /// every conflict pair was evaluated).  Determines which work
    /// formula [`JobMetrics::work_units`] applies.
    pub k: usize,
    /// Algorithm name that served the job.
    pub algorithm: String,
    /// Backend name (`native` / `xla`).
    pub backend: String,
    /// Wall-clock seconds.
    pub seconds: f64,
}

impl JobMetrics {
    /// Triplet comparisons this job actually performed: `n³/6` for a
    /// dense job (`k == 0`), `n·k²` for a truncated PKNN job — the
    /// O(n·k²) cost model of DESIGN.md §9.  Charging sparse jobs the
    /// dense formula would overstate their throughput by `Θ(n²/k²)`.
    pub fn work_units(&self) -> f64 {
        let n = self.n as f64;
        if self.k == 0 {
            n * n * n / 6.0
        } else {
            let k = self.k as f64;
            n * k * k
        }
    }

    /// Domain throughput: [`JobMetrics::work_units`] per second.
    pub fn triplets_per_sec(&self) -> f64 {
        self.work_units() / self.seconds.max(1e-12)
    }
}

/// How many shards the registry spreads recording threads across.
const SHARDS: usize = 16;

thread_local! {
    /// This thread's shard index (assigned once, round-robin).
    static MY_SHARD: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
}

/// Accumulating registry with lock-sharded, `&self` recording and
/// sequence-ordered snapshots (safe to share behind an `Arc` across the
/// serving layer's worker threads).
pub struct MetricsRegistry {
    shards: Vec<Mutex<Vec<(u64, JobMetrics)>>>,
    /// Global completion-order stamp.
    seq: AtomicU64,
    /// Round-robin assignment of threads to shards.
    next_shard: AtomicUsize,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry {
            shards: (0..SHARDS).map(|_| Mutex::new(Vec::new())).collect(),
            seq: AtomicU64::new(0),
            next_shard: AtomicUsize::new(0),
        }
    }
}

impl MetricsRegistry {
    /// Empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Record one completed job.  Takes `&self`: the calling thread
    /// locks only its own shard (assigned round-robin on first use), so
    /// concurrent workers recording different jobs do not serialize.
    pub fn record(&self, m: JobMetrics) {
        let shard = MY_SHARD.with(|s| {
            if s.get() == usize::MAX {
                s.set(self.next_shard.fetch_add(1, Ordering::Relaxed) % self.shards.len());
            }
            s.get()
        });
        let stamp = self.seq.fetch_add(1, Ordering::Relaxed);
        // A poisoned shard (a panic while holding the lock) only loses
        // that shard's history; recording must not propagate the panic.
        if let Ok(mut jobs) = self.shards[shard].lock() {
            jobs.push((stamp, m));
        }
    }

    /// Consistent view of every recorded job in completion order
    /// (sequence-stamped at [`MetricsRegistry::record`] time).  Shards
    /// are locked one at a time, so writers are never globally stalled.
    pub fn snapshot(&self) -> Vec<JobMetrics> {
        let mut stamped: Vec<(u64, JobMetrics)> = Vec::new();
        for shard in &self.shards {
            if let Ok(jobs) = shard.lock() {
                stamped.extend(jobs.iter().cloned());
            }
        }
        stamped.sort_by_key(|(stamp, _)| *stamp);
        stamped.into_iter().map(|(_, m)| m).collect()
    }

    /// All recorded jobs, in completion order (alias of
    /// [`MetricsRegistry::snapshot`], kept for the pre-serve call sites).
    pub fn jobs(&self) -> Vec<JobMetrics> {
        self.snapshot()
    }

    /// Number of jobs recorded so far.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().map(|j| j.len()).unwrap_or(0)).sum()
    }

    /// Has nothing been recorded yet?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total wall-clock seconds across recorded jobs.
    pub fn total_seconds(&self) -> f64 {
        self.shards
            .iter()
            .map(|s| s.lock().map(|j| j.iter().map(|(_, m)| m.seconds).sum::<f64>()).unwrap_or(0.0))
            .sum()
    }

    /// Render a short text summary.
    pub fn summary(&self) -> String {
        let jobs = self.snapshot();
        if jobs.is_empty() {
            return "no jobs".into();
        }
        let total: f64 = jobs.iter().map(|j| j.seconds).sum();
        let mean_tput =
            jobs.iter().map(|j| j.triplets_per_sec()).sum::<f64>() / jobs.len() as f64;
        format!(
            "{} job(s), {:.3}s total, mean throughput {:.2}M triplets/s",
            jobs.len(),
            total,
            mean_tput / 1e6
        )
    }

    /// Plaintext scrape rendering (Prometheus text exposition style):
    /// job totals plus per-algorithm counts/seconds/work, served by the
    /// `STATS` frame and the HTTP scrape path of `paldx serve`.
    pub fn scrape(&self) -> String {
        let jobs = self.snapshot();
        let mut out = String::new();
        out.push_str("# TYPE paldx_jobs_total counter\n");
        out.push_str(&format!("paldx_jobs_total {}\n", jobs.len()));
        out.push_str("# TYPE paldx_job_seconds_total counter\n");
        out.push_str(&format!(
            "paldx_job_seconds_total {:.6}\n",
            jobs.iter().map(|j| j.seconds).sum::<f64>()
        ));
        out.push_str("# TYPE paldx_work_units_total counter\n");
        out.push_str(&format!(
            "paldx_work_units_total {:.3e}\n",
            jobs.iter().map(|j| j.work_units()).sum::<f64>()
        ));
        // Per-algorithm breakdown, insertion-ordered by first appearance.
        let mut algs: Vec<(&str, usize, f64)> = Vec::new();
        for j in &jobs {
            match algs.iter_mut().find(|(a, _, _)| *a == j.algorithm) {
                Some((_, count, secs)) => {
                    *count += 1;
                    *secs += j.seconds;
                }
                None => algs.push((&j.algorithm, 1, j.seconds)),
            }
        }
        for (alg, count, secs) in algs {
            out.push_str(&format!("paldx_jobs_total{{algorithm=\"{alg}\"}} {count}\n"));
            out.push_str(&format!("paldx_job_seconds_total{{algorithm=\"{alg}\"}} {secs:.6}\n"));
        }
        out
    }
}

/// Inject a `{key="value"}` label pair into every sample line of a
/// plaintext scrape, merging with labels already present — how the
/// router's aggregated fleet scrape attributes each backend's metrics
/// to its shard (DESIGN.md §14).  Comment lines (`# TYPE …`) and lines
/// that don't parse as `name[{labels}] value` pass through unchanged;
/// `value` is escaped per the Prometheus text exposition rules.
pub fn relabel_scrape(scrape: &str, key: &str, value: &str) -> String {
    let escaped: String = value
        .chars()
        .flat_map(|c| match c {
            '\\' => vec!['\\', '\\'],
            '"' => vec!['\\', '"'],
            '\n' => vec!['\\', 'n'],
            c => vec![c],
        })
        .collect();
    let pair = format!("{key}=\"{escaped}\"");
    let mut out = String::with_capacity(scrape.len() + scrape.lines().count() * pair.len());
    for line in scrape.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            out.push_str(line);
            out.push('\n');
            continue;
        }
        // `name{a="b"} v` → splice into the existing label set;
        // `name v`       → insert a fresh one before the space.
        if let Some(brace) = line.find('{') {
            out.push_str(&line[..brace + 1]);
            out.push_str(&pair);
            out.push(',');
            out.push_str(&line[brace + 1..]);
        } else if let Some(space) = line.find(' ') {
            out.push_str(&line[..space]);
            out.push('{');
            out.push_str(&pair);
            out.push('}');
            out.push_str(&line[space..]);
        } else {
            out.push_str(line);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(n: usize, k: usize, seconds: f64) -> JobMetrics {
        JobMetrics { n, k, algorithm: "x".into(), backend: "Native".into(), seconds }
    }

    #[test]
    fn throughput_math_pins_both_formulas() {
        // Dense (k = 0): the classic n³/6 triplet count.
        let dense = job(600, 0, 2.0);
        let want_dense = 600.0f64.powi(3) / 6.0 / 2.0;
        assert!((dense.triplets_per_sec() - want_dense).abs() < 1.0);
        // Truncated (k > 0): O(n·k²) actual work — NOT n³/6.  At
        // n = 600, k = 10 the dense formula would overstate the work
        // (and hence throughput) by a factor of 600.
        let sparse = job(600, 10, 2.0);
        let want_sparse = 600.0 * 10.0 * 10.0 / 2.0;
        assert!((sparse.triplets_per_sec() - want_sparse).abs() < 1e-6);
        assert!((dense.work_units() / sparse.work_units() - 600.0).abs() < 1e-9);
    }

    #[test]
    fn registry_summary() {
        let r = MetricsRegistry::default();
        assert_eq!(r.summary(), "no jobs");
        assert!(r.is_empty());
        r.record(job(100, 0, 0.5));
        assert!(r.summary().contains("1 job(s)"));
        assert!((r.total_seconds() - 0.5).abs() < 1e-12);
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn snapshot_preserves_completion_order() {
        let r = MetricsRegistry::new();
        for n in [10usize, 20, 30, 40] {
            r.record(job(n, 0, 0.1));
        }
        let ns: Vec<usize> = r.snapshot().iter().map(|j| j.n).collect();
        assert_eq!(ns, vec![10, 20, 30, 40]);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let r = MetricsRegistry::new();
        const THREADS: usize = 8;
        const PER: usize = 500;
        std::thread::scope(|scope| {
            for t in 0..THREADS {
                let r = &r;
                scope.spawn(move || {
                    for i in 0..PER {
                        r.record(job(t * PER + i + 2, (t + i) % 3, 1e-4));
                    }
                });
            }
        });
        let jobs = r.snapshot();
        assert_eq!(jobs.len(), THREADS * PER);
        assert_eq!(r.len(), THREADS * PER);
        // Every (thread, i) slot arrived exactly once.
        let mut ns: Vec<usize> = jobs.iter().map(|j| j.n).collect();
        ns.sort_unstable();
        ns.dedup();
        assert_eq!(ns.len(), THREADS * PER);
        assert!((r.total_seconds() - THREADS as f64 * PER as f64 * 1e-4).abs() < 1e-6);
    }

    #[test]
    fn scrape_renders_totals_and_per_algorithm_lines() {
        let r = MetricsRegistry::new();
        r.record(JobMetrics {
            n: 64,
            k: 0,
            algorithm: "opt-pairwise".into(),
            backend: "Native".into(),
            seconds: 0.25,
        });
        r.record(JobMetrics {
            n: 64,
            k: 8,
            algorithm: "knn-opt-pairwise".into(),
            backend: "Native".into(),
            seconds: 0.05,
        });
        let text = r.scrape();
        assert!(text.contains("paldx_jobs_total 2"), "{text}");
        assert!(text.contains("paldx_jobs_total{algorithm=\"opt-pairwise\"} 1"), "{text}");
        assert!(text.contains("paldx_jobs_total{algorithm=\"knn-opt-pairwise\"} 1"), "{text}");
        assert!(text.contains("paldx_work_units_total"), "{text}");
    }

    #[test]
    fn relabel_injects_and_merges_labels() {
        let scrape = "# TYPE paldx_jobs_total counter\n\
                      paldx_jobs_total 3\n\
                      paldx_jobs_total{algorithm=\"hybrid\"} 2\n\
                      \n\
                      paldx_pool_bytes 4096\n";
        let out = relabel_scrape(scrape, "backend", "127.0.0.1:7465");
        assert!(out.contains("# TYPE paldx_jobs_total counter\n"), "{out}");
        assert!(out.contains("paldx_jobs_total{backend=\"127.0.0.1:7465\"} 3\n"), "{out}");
        assert!(
            out.contains("paldx_jobs_total{backend=\"127.0.0.1:7465\",algorithm=\"hybrid\"} 2\n"),
            "{out}"
        );
        assert!(out.contains("paldx_pool_bytes{backend=\"127.0.0.1:7465\"} 4096\n"), "{out}");
        // Label values are escaped per the exposition format.
        let out = relabel_scrape("m 1\n", "b", "quo\"te\\x");
        assert!(out.contains("m{b=\"quo\\\"te\\\\x\"} 1\n"), "{out}");
        // Relabeling a real registry scrape keeps every sample line.
        let r = MetricsRegistry::new();
        r.record(job(64, 0, 0.1));
        let plain = r.scrape();
        let tagged = relabel_scrape(&plain, "backend", "a:1");
        assert_eq!(plain.lines().count(), tagged.lines().count());
        for line in tagged.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.contains("backend=\"a:1\""), "{line}");
        }
    }
}
