//! Job metrics registry (throughput accounting for the e2e drivers).

/// Metrics of one completed job.
#[derive(Clone, Debug)]
pub struct JobMetrics {
    /// Problem size (points).
    pub n: usize,
    /// Algorithm name that served the job.
    pub algorithm: String,
    /// Backend name (`native` / `xla`).
    pub backend: String,
    /// Wall-clock seconds.
    pub seconds: f64,
}

impl JobMetrics {
    /// Triplet-comparisons per second (n^3/6 per job) — the domain
    /// throughput metric the benches report.
    pub fn triplets_per_sec(&self) -> f64 {
        let n = self.n as f64;
        n * n * n / 6.0 / self.seconds.max(1e-12)
    }
}

/// Accumulating registry.
#[derive(Default)]
pub struct MetricsRegistry {
    jobs: Vec<JobMetrics>,
}

impl MetricsRegistry {
    /// Record one completed job.
    pub fn record(&mut self, m: JobMetrics) {
        self.jobs.push(m);
    }

    /// All recorded jobs, in completion order.
    pub fn jobs(&self) -> &[JobMetrics] {
        &self.jobs
    }

    /// Total wall-clock seconds across recorded jobs.
    pub fn total_seconds(&self) -> f64 {
        self.jobs.iter().map(|j| j.seconds).sum()
    }

    /// Render a short text summary.
    pub fn summary(&self) -> String {
        if self.jobs.is_empty() {
            return "no jobs".into();
        }
        let total = self.total_seconds();
        let mean_tput =
            self.jobs.iter().map(|j| j.triplets_per_sec()).sum::<f64>() / self.jobs.len() as f64;
        format!(
            "{} job(s), {:.3}s total, mean throughput {:.2}M triplets/s",
            self.jobs.len(),
            total,
            mean_tput / 1e6
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = JobMetrics { n: 600, algorithm: "x".into(), backend: "Native".into(), seconds: 2.0 };
        let want = 600.0f64.powi(3) / 6.0 / 2.0;
        assert!((m.triplets_per_sec() - want).abs() < 1.0);
    }

    #[test]
    fn registry_summary() {
        let mut r = MetricsRegistry::default();
        assert_eq!(r.summary(), "no jobs");
        r.record(JobMetrics { n: 100, algorithm: "a".into(), backend: "Native".into(), seconds: 0.5 });
        assert!(r.summary().contains("1 job(s)"));
        assert!((r.total_seconds() - 0.5).abs() < 1e-12);
    }
}
