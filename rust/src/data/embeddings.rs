//! Synthetic word embeddings for the Section 7 text-analysis application.
//!
//! The paper embeds 2712 words from Shakespeare's sonnets with pre-trained
//! fastText vectors.  Neither the corpus tooling nor the embedding model is
//! available offline, so this module builds a deterministic synthetic
//! embedding with the *geometry that Section 7 actually exercises*:
//!
//! * a vocabulary of pseudo-words with Zipfian frequency ranks;
//! * semantic clusters of widely varying size, density, and radius —
//!   including a dense, populous cluster around a probe word ("guilt": 20
//!   strong ties in the paper) and a sparse, tight cluster around another
//!   ("halt": 5 strong ties);
//! * background words scattered broadly so that absolute-distance cutoffs
//!   tuned for one neighborhood fail on the other — the paper's headline
//!   qualitative result (Fig. 12).

use crate::core::Mat;
use crate::data::distmat;
use crate::data::prng::Rng;

/// A synthetic embedded vocabulary.
pub struct EmbeddedVocab {
    /// Word strings, index-aligned with embedding rows.
    pub words: Vec<String>,
    /// `n x dim` embedding matrix.
    pub vectors: Mat,
    /// Ground-truth cluster id per word (background = usize::MAX).
    pub cluster: Vec<usize>,
    /// Names of the probe clusters, index = cluster id.
    pub cluster_names: Vec<String>,
}

/// Deterministic pseudo-word generator (CV syllables keyed on the rng).
fn pseudo_word(rng: &mut Rng, syllables: usize) -> String {
    const C: &[u8] = b"bcdfghklmnprstvw";
    const V: &[u8] = b"aeiou";
    let mut w = String::new();
    for _ in 0..syllables {
        w.push(C[rng.below(C.len())] as char);
        w.push(V[rng.below(V.len())] as char);
    }
    w
}

/// Cluster specification: (name, member count, radius around the center).
pub struct ClusterSpec {
    /// Cluster (seed word) name.
    pub name: &'static str,
    /// Member count.
    pub size: usize,
    /// Radius around the cluster center.
    pub radius: f32,
}

/// The Section 7 configuration: n words total, dim-dimensional embeddings,
/// a dense "guilt"-like cluster, a sparse "halt"-like cluster, several
/// medium clusters, and Zipf-distributed background words.
pub fn sonnets_like(n: usize, dim: usize, seed: u64) -> EmbeddedVocab {
    let specs = vec![
        ClusterSpec { name: "guilt", size: 21, radius: 0.55 },
        ClusterSpec { name: "halt", size: 6, radius: 0.28 },
        ClusterSpec { name: "love", size: 40, radius: 0.8 },
        ClusterSpec { name: "time", size: 30, radius: 0.7 },
        ClusterSpec { name: "beauty", size: 25, radius: 0.6 },
    ];
    build(n, dim, seed, specs)
}

/// Build a synthetic embedded vocabulary from cluster specs.
pub fn build(n: usize, dim: usize, seed: u64, specs: Vec<ClusterSpec>) -> EmbeddedVocab {
    let clustered: usize = specs.iter().map(|s| s.size).sum();
    assert!(clustered < n, "cluster members must fit in the vocabulary");
    let mut rng = Rng::new(seed);

    let mut words = Vec::with_capacity(n);
    let mut vectors = Mat::zeros(n, dim);
    let mut cluster = vec![usize::MAX; n];
    let mut cluster_names = Vec::new();

    // Cluster centers: well-separated random directions far outside the
    // background shell, so probe clusters are crisp (their within-cluster
    // distances ≈ radius, cluster-to-background ≈ several units).
    let sep = 9.0f32;
    let mut row = 0usize;
    for (cid, spec) in specs.iter().enumerate() {
        cluster_names.push(spec.name.to_string());
        let mut center = vec![0.0f32; dim];
        let mut norm = 0.0f64;
        for v in center.iter_mut() {
            *v = rng.normal() as f32;
            norm += (*v as f64).powi(2);
        }
        let norm = norm.sqrt().max(1e-9) as f32;
        for v in center.iter_mut() {
            *v = *v / norm * sep;
        }
        for k in 0..spec.size {
            // First member carries the probe word itself.
            words.push(if k == 0 {
                spec.name.to_string()
            } else {
                format!("{}_{}", pseudo_word(&mut rng, 2), spec.name)
            });
            cluster[row] = cid;
            // Scatter uniformly within the cluster radius (denser clusters
            // come from bigger size at similar radius).
            for j in 0..dim {
                vectors[(row, j)] =
                    center[j] + spec.radius * rng.normal() as f32 / (dim as f32).sqrt();
            }
            row += 1;
        }
        // Fringe: unrelated words orbiting just outside the cluster
        // (2.5–4x its radius).  These are what an absolute-distance cutoff
        // tuned on a *looser* cluster wrongly pulls in — the Figure 12
        // pitfall — while staying outside PaLD's relative-distance ties.
        let fringe = (spec.size).min(n - clustered - 1);
        for _ in 0..fringe {
            if row >= n {
                break;
            }
            words.push(pseudo_word(&mut rng, 3));
            let dist = spec.radius * rng.uniform_in(2.5, 4.0);
            for j in 0..dim {
                vectors[(row, j)] =
                    center[j] + dist * rng.normal() as f32 / (dim as f32).sqrt();
            }
            row += 1;
        }
    }
    // Background vocabulary: broad shell of words (norm ~ 2..6), inside
    // the cluster orbit, so absolute-distance cutoffs tuned for one
    // cluster leak into unrelated words while PaLD's relative-distance
    // ties stay within clusters.
    while row < n {
        let syl = 1 + rng.below(3);
        words.push(pseudo_word(&mut rng, syl + 1));
        let mut norm = 0.0f64;
        let mut v = vec![0.0f32; dim];
        for x in v.iter_mut() {
            *x = rng.normal() as f32;
            norm += (*x as f64).powi(2);
        }
        let norm = norm.sqrt().max(1e-9) as f32;
        let target = rng.uniform_in(2.0, 6.0);
        for (j, x) in v.iter().enumerate() {
            vectors[(row, j)] = x / norm * target;
        }
        row += 1;
    }

    EmbeddedVocab { words, vectors, cluster, cluster_names }
}

impl EmbeddedVocab {
    /// Number of embedded words.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// `true` when the vocabulary is empty.
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Index of an exact word, if present.
    pub fn index_of(&self, word: &str) -> Option<usize> {
        self.words.iter().position(|w| w == word)
    }

    /// Euclidean distance matrix over the vocabulary (the paper's choice).
    pub fn distance_matrix(&self) -> Mat {
        distmat::euclidean(&self.vectors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sonnets_like_shape_and_probes() {
        let v = sonnets_like(500, 32, 42);
        assert_eq!(v.len(), 500);
        assert_eq!(v.vectors.rows(), 500);
        assert!(v.index_of("guilt").is_some());
        assert!(v.index_of("halt").is_some());
        // cluster sizes as specified
        assert_eq!(v.cluster.iter().filter(|&&c| c == 0).count(), 21);
        assert_eq!(v.cluster.iter().filter(|&&c| c == 1).count(), 6);
    }

    #[test]
    fn cluster_members_are_nearer_than_background() {
        let v = sonnets_like(400, 32, 7);
        let d = v.distance_matrix();
        let g = v.index_of("guilt").unwrap();
        let mut within = Vec::new();
        let mut outside = Vec::new();
        for i in 0..v.len() {
            if i == g {
                continue;
            }
            if v.cluster[i] == 0 {
                within.push(d[(g, i)]);
            } else if v.cluster[i] == usize::MAX {
                outside.push(d[(g, i)]);
            }
        }
        let max_within = within.iter().cloned().fold(0.0f32, f32::max);
        let mut sorted = outside.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // the whole guilt cluster is closer than ~95% of background words
        let p5 = sorted[sorted.len() / 20];
        assert!(max_within < p5 * 2.0, "max_within={max_within} p5={p5}");
    }

    #[test]
    fn deterministic() {
        let a = sonnets_like(300, 16, 3);
        let b = sonnets_like(300, 16, 3);
        assert_eq!(a.words, b.words);
        assert_eq!(a.vectors.as_slice(), b.vectors.as_slice());
    }

    #[test]
    fn words_unique_enough() {
        let v = sonnets_like(800, 16, 5);
        let mut w = v.words.clone();
        w.sort();
        w.dedup();
        // pseudo-word collisions happen, but the vocabulary is mostly unique
        assert!(w.len() > 700, "unique={}", w.len());
    }
}
