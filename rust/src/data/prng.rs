//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 for seeding, Xoshiro256++ for the stream — the standard
//! pairing recommended by the xoshiro authors.  No external crates (the
//! offline cache has no `rand`), and fully reproducible across platforms.

/// SplitMix64: used to expand a single `u64` seed into Xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Generator starting from `seed`.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64 pseudo-random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeded generator; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Next 64 pseudo-random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased enough for
    /// our n << 2^64 use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample from a Zipf(s) distribution over `1..=n` by inverse CDF.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Precomputing the CDF per call would be wasteful; callers that
        // sample many values should use `zipf_table`.
        let table = zipf_table(n, s);
        let u = self.uniform();
        match table.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) | Err(i) => i.min(n - 1) + 1,
        }
    }
}

/// Cumulative Zipf(s) table over `1..=n` for repeated sampling.
pub fn zipf_table(n: usize, s: f64) -> Vec<f64> {
    let mut cdf = Vec::with_capacity(n);
    let mut total = 0.0;
    for k in 1..=n {
        total += 1.0 / (k as f64).powf(s);
        cdf.push(total);
    }
    for v in &mut cdf {
        *v /= total;
    }
    cdf
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.03, "mean={m}");
        assert!((v - 1.0).abs() < 0.05, "var={v}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(5);
        let p = r.permutation(64);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_head_heavier_than_tail() {
        let mut r = Rng::new(9);
        let table = zipf_table(100, 1.1);
        assert!(table[0] > 0.1); // rank 1 carries noticeable mass
        let mut head = 0;
        for _ in 0..1000 {
            let u = r.uniform();
            let k = match table.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
                Ok(i) | Err(i) => i + 1,
            };
            if k <= 10 {
                head += 1;
            }
        }
        assert!(head > 400, "head={head}");
    }
}
