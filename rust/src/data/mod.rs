//! Data substrates: PRNG, distance-matrix generation, synthetic graphs with
//! all-pairs shortest paths, and synthetic word embeddings.
//!
//! Everything here is built from scratch (the offline cargo cache has no
//! `rand`), deterministic given a seed, and sized to the paper's workloads.

pub mod distmat;
pub mod embeddings;
pub mod graph;
pub mod prng;
