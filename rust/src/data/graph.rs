//! Sparse graphs and all-pairs shortest paths.
//!
//! Substrate for the paper's Appendix C (SNAP collaboration networks
//! ca-GrQc / ca-HepPh / ca-CondMat).  The SNAP downloads are unavailable
//! offline, so [`collaboration_network`] generates community-structured
//! graphs with the same qualitative properties (heavy-tailed degrees from
//! preferential attachment, dense triangle-rich communities, sparse
//! inter-community bridges) at the same vertex counts, and [`Csr::apsp`]
//! produces the distance matrix via per-source BFS exactly as the paper
//! does ("distance matrices by computing all-pairs shortest path
//! distances").

use crate::core::Mat;
use crate::data::prng::Rng;

/// Compressed-sparse-row undirected graph.
#[derive(Clone, Debug)]
pub struct Csr {
    offsets: Vec<u32>,
    neighbors: Vec<u32>,
}

impl Csr {
    /// Build from an undirected edge list; duplicates and self-loops are
    /// dropped.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Csr {
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(a, b) in edges {
            if a != b {
                adj[a as usize].push(b);
                adj[b as usize].push(a);
            }
        }
        let mut offsets = Vec::with_capacity(n + 1);
        let mut neighbors = Vec::new();
        offsets.push(0);
        for list in &mut adj {
            list.sort_unstable();
            list.dedup();
            neighbors.extend_from_slice(list);
            offsets.push(neighbors.len() as u32);
        }
        Csr { offsets, neighbors }
    }

    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.neighbors.len() / 2
    }

    /// Adjacency list of vertex `v`.
    #[inline]
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.neighbors[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    /// Degree of vertex `v`.
    pub fn degree(&self, v: usize) -> usize {
        self.neighbors(v).len()
    }

    /// Single-source BFS distances (u16::MAX = unreachable).
    pub fn bfs(&self, src: usize, dist: &mut [u16], queue: &mut Vec<u32>) {
        dist.fill(u16::MAX);
        queue.clear();
        dist[src] = 0;
        queue.push(src as u32);
        let mut head = 0;
        while head < queue.len() {
            let v = queue[head] as usize;
            head += 1;
            let dv = dist[v];
            for &w in self.neighbors(v) {
                let w = w as usize;
                if dist[w] == u16::MAX {
                    dist[w] = dv + 1;
                    queue.push(w as u32);
                }
            }
        }
    }

    /// Largest connected component, as (vertex-remapped graph, old ids).
    pub fn largest_component(&self) -> (Csr, Vec<u32>) {
        let n = self.num_vertices();
        let mut comp = vec![u32::MAX; n];
        let mut sizes: Vec<(u32, u32)> = Vec::new(); // (comp id, size)
        let mut dist = vec![0u16; n];
        let mut queue = Vec::new();
        let mut cid = 0u32;
        for s in 0..n {
            if comp[s] == u32::MAX {
                self.bfs(s, &mut dist, &mut queue);
                let mut size = 0;
                for &v in queue.iter() {
                    comp[v as usize] = cid;
                    size += 1;
                }
                sizes.push((cid, size));
                cid += 1;
            }
        }
        let best = sizes.iter().max_by_key(|&&(_, s)| s).unwrap().0;
        let keep: Vec<u32> = (0..n as u32).filter(|&v| comp[v as usize] == best).collect();
        let mut remap = vec![u32::MAX; n];
        for (new, &old) in keep.iter().enumerate() {
            remap[old as usize] = new as u32;
        }
        let mut edges = Vec::new();
        for &old in &keep {
            for &w in self.neighbors(old as usize) {
                if old < w && remap[w as usize] != u32::MAX {
                    edges.push((remap[old as usize], remap[w as usize]));
                }
            }
        }
        (Csr::from_edges(keep.len(), &edges), keep)
    }

    /// All-pairs shortest-path distance matrix via n BFS traversals.
    ///
    /// Unreachable pairs get `2 * diameter` (callers should normally pass
    /// the largest connected component).  A tiny deterministic jitter
    /// (`+ v * 1e-4` keyed on the pair) is added off-diagonal so the
    /// resulting matrix is tie-free and strict-mode PaLD semantics apply —
    /// hop-count APSP is otherwise massively tied.
    pub fn apsp(&self, jitter: bool) -> Mat {
        let n = self.num_vertices();
        let mut d = Mat::zeros(n, n);
        let mut dist = vec![0u16; n];
        let mut queue = Vec::new();
        let mut diam = 1u16;
        for s in 0..n {
            self.bfs(s, &mut dist, &mut queue);
            for v in 0..n {
                if dist[v] != u16::MAX && dist[v] > diam {
                    diam = dist[v];
                }
                d[(s, v)] = if dist[v] == u16::MAX { -1.0 } else { dist[v] as f32 };
            }
        }
        let unreachable = 2.0 * diam as f32;
        let mut rng = Rng::new(0x9e37);
        for x in 0..n {
            for y in (x + 1)..n {
                let mut v = d[(x, y)];
                if v < 0.0 {
                    v = unreachable;
                }
                if jitter {
                    v += rng.uniform_in(0.0, 1e-3);
                }
                d[(x, y)] = v;
                d[(y, x)] = v;
            }
            d[(x, x)] = 0.0;
        }
        d
    }
}

/// Community-structured collaboration-network generator.
///
/// `n` vertices are split into communities with sizes drawn from a
/// heavy-tailed distribution; inside a community, vertices attach
/// preferentially (collaboration graphs are triangle-dense, so each new
/// vertex links to a random clique of `m_intra` earlier members); a small
/// fraction `p_bridge` of vertices also link to a member of another
/// community.  This mirrors the degree/clustering structure of the SNAP
/// ca-* graphs closely enough for Appendix C, whose runtime depends only on
/// the APSP matrix size.
pub fn collaboration_network(n: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    // Heavy-tailed community sizes: repeatedly carve off Pareto-ish chunks.
    let mut sizes = Vec::new();
    let mut left = n;
    while left > 0 {
        let frac = (rng.uniform().powf(2.0) * 0.03 + 0.002).min(1.0);
        let s = std::cmp::min(((n as f64 * frac) as usize).max(3), left);
        sizes.push(s);
        left -= s;
    }
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let mut base = 0usize;
    let mut starts = Vec::new();
    for &s in &sizes {
        starts.push(base);
        if s == 1 {
            base += 1;
            continue;
        }
        // Preferential attachment with clique joins (m = 2):
        // vertex i joins by picking an anchor ~ degree-weighted, linking to
        // the anchor and one of its neighbors (forming a triangle).
        let mut endpoints: Vec<u32> = Vec::new(); // degree-weighted pool
        edges.push((base as u32, (base + 1) as u32));
        endpoints.extend([base as u32, (base + 1) as u32]);
        if s > 2 {
            edges.push((base as u32, (base + 2) as u32));
            edges.push(((base + 1) as u32, (base + 2) as u32));
            endpoints.extend([base as u32, (base + 2) as u32, (base + 1) as u32, (base + 2) as u32]);
        }
        for i in 3..s {
            let v = (base + i) as u32;
            let anchor = endpoints[rng.below(endpoints.len())];
            edges.push((v, anchor));
            endpoints.extend([v, anchor]);
            // close a triangle through a second endpoint
            let second = endpoints[rng.below(endpoints.len())];
            if second != v && second != anchor {
                edges.push((v, second));
                endpoints.extend([v, second]);
            }
        }
        base += s;
    }
    // Bridges: connect consecutive communities (guaranteeing one component)
    // plus a few random long-range collaborations.
    for w in 1..sizes.len() {
        let a = starts[w - 1] + rng.below(sizes[w - 1]);
        let b = starts[w] + rng.below(sizes[w]);
        edges.push((a as u32, b as u32));
    }
    let extra = (n / 20).max(1);
    for _ in 0..extra {
        let a = rng.below(n) as u32;
        let b = rng.below(n) as u32;
        if a != b {
            edges.push((a, b));
        }
    }
    Csr::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::distmat::validate;

    #[test]
    fn csr_from_edges_dedups() {
        let g = Csr::from_edges(4, &[(0, 1), (1, 0), (1, 2), (2, 3), (3, 3)]);
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.degree(3), 1);
    }

    #[test]
    fn bfs_distances_on_path_graph() {
        let g = Csr::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let mut dist = vec![0u16; 5];
        let mut q = Vec::new();
        g.bfs(0, &mut dist, &mut q);
        assert_eq!(dist, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn apsp_is_valid_distance_matrix() {
        let g = collaboration_network(120, 4);
        let (lcc, _) = g.largest_component();
        let d = lcc.apsp(true);
        validate(&d).unwrap();
    }

    #[test]
    fn largest_component_connects_everything() {
        let g = collaboration_network(300, 9);
        let (lcc, ids) = g.largest_component();
        assert!(lcc.num_vertices() >= 290, "lcc={}", lcc.num_vertices());
        assert_eq!(ids.len(), lcc.num_vertices());
        let mut dist = vec![0u16; lcc.num_vertices()];
        let mut q = Vec::new();
        lcc.bfs(0, &mut dist, &mut q);
        assert!(dist.iter().all(|&v| v != u16::MAX));
    }

    #[test]
    fn collaboration_network_is_heavy_tailed_and_clustered() {
        let g = collaboration_network(1000, 1);
        let n = g.num_vertices();
        let degs: Vec<usize> = (0..n).map(|v| g.degree(v)).collect();
        let max = *degs.iter().max().unwrap();
        let mean = degs.iter().sum::<usize>() as f64 / n as f64;
        assert!(max as f64 > 5.0 * mean, "max={max} mean={mean}");
        // Sparse, like collaboration nets.
        assert!(g.num_edges() < 10 * n);
    }

    #[test]
    fn generator_deterministic() {
        let a = collaboration_network(200, 3);
        let b = collaboration_network(200, 3);
        assert_eq!(a.num_edges(), b.num_edges());
        assert_eq!(a.neighbors(17), b.neighbors(17));
    }
}
