//! Distance-matrix generation.
//!
//! The paper's sequential/parallel studies run on "randomly generated dense
//! distance matrices"; the applications derive distances from embeddings
//! (Euclidean) or graphs (shortest paths).  All generators here produce
//! symmetric matrices with zero diagonal, and the `*_tie_free` variants
//! guarantee distinct off-diagonal values so that `TieMode::Strict` is
//! well-defined (ties are measure-zero for continuous data — the paper's
//! argument for eliding tie checks).

use crate::core::Mat;
use crate::data::prng::Rng;

/// Random dense distance matrix with i.i.d. uniform(0.1, 1.1) entries.
/// Not guaranteed tie-free (f32 collisions are possible, if unlikely).
pub fn random_uniform(n: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    let mut d = Mat::zeros(n, n);
    for x in 0..n {
        for y in (x + 1)..n {
            let v = rng.uniform_in(0.1, 1.1);
            d[(x, y)] = v;
            d[(y, x)] = v;
        }
    }
    d
}

/// Random distance matrix whose off-diagonal values are all distinct:
/// a shuffled ladder `base + k*eps` — strict-mode semantics are exact.
pub fn random_tie_free(n: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    let m = n * (n - 1) / 2;
    let mut vals: Vec<f32> = (0..m).map(|k| 0.5 + (k as f32 + 1.0) / m as f32).collect();
    rng.shuffle(&mut vals);
    let mut d = Mat::zeros(n, n);
    let mut k = 0;
    for x in 0..n {
        for y in (x + 1)..n {
            d[(x, y)] = vals[k];
            d[(y, x)] = vals[k];
            k += 1;
        }
    }
    d
}

/// Random distance matrix with small-integer entries — guaranteed ties,
/// used to exercise `TieMode::Split`.
pub fn random_tied(n: usize, seed: u64, levels: u32) -> Mat {
    let mut rng = Rng::new(seed);
    let mut d = Mat::zeros(n, n);
    for x in 0..n {
        for y in (x + 1)..n {
            let v = (rng.below(levels as usize) + 1) as f32;
            d[(x, y)] = v;
            d[(y, x)] = v;
        }
    }
    d
}

/// Distance matrix of `n` points drawn (with repetition) from `distinct`
/// locations on a line: maximally tie-heavy, including exact zero
/// distances between duplicated points.  This is the adversarial input
/// for `TieMode::Split` (strict mode is undefined on it by design).
pub fn random_duplicated(n: usize, seed: u64, distinct: usize) -> Mat {
    assert!(distinct >= 2);
    let mut rng = Rng::new(seed);
    // Distinct locations spaced >= 1 apart so cross-location distances
    // never collide with the zero self-distances.
    let locs: Vec<f32> = (0..distinct).map(|k| 2.0 * k as f32 + 1.0).collect();
    let assign: Vec<f32> = (0..n).map(|_| locs[rng.below(distinct)]).collect();
    Mat::from_fn(n, n, |x, y| (assign[x] - assign[y]).abs())
}

/// Euclidean distance matrix from a point cloud (rows of `pts`).
pub fn euclidean(pts: &Mat) -> Mat {
    let n = pts.rows();
    let mut d = Mat::zeros(n, n);
    for x in 0..n {
        let px = pts.row(x);
        for y in (x + 1)..n {
            let py = pts.row(y);
            let mut s = 0.0f64;
            for (a, b) in px.iter().zip(py) {
                let diff = (a - b) as f64;
                s += diff * diff;
            }
            let v = s.sqrt() as f32;
            d[(x, y)] = v;
            d[(y, x)] = v;
        }
    }
    d
}

/// Gaussian-mixture point cloud: `sizes[i]` points around center i.
///
/// `spread[i]` controls the within-cluster standard deviation, letting
/// tests build the paper's motivating geometry: communities of very
/// different density that a single absolute distance threshold cannot
/// capture.
pub fn gaussian_clusters(
    dim: usize,
    sizes: &[usize],
    spread: &[f32],
    sep: f32,
    seed: u64,
) -> Mat {
    assert_eq!(sizes.len(), spread.len());
    let mut rng = Rng::new(seed);
    let k = sizes.len();
    // Random unit-ish directions for cluster centers, scaled by `sep`.
    let mut centers = Mat::zeros(k, dim);
    for c in 0..k {
        let row = centers.row_mut(c);
        let mut norm = 0.0f64;
        for v in row.iter_mut() {
            *v = rng.normal() as f32;
            norm += (*v as f64) * (*v as f64);
        }
        let norm = norm.sqrt().max(1e-9) as f32;
        for v in row.iter_mut() {
            *v = *v / norm * sep;
        }
    }
    let n: usize = sizes.iter().sum();
    let mut pts = Mat::zeros(n, dim);
    let mut row = 0;
    for c in 0..k {
        for _ in 0..sizes[c] {
            for j in 0..dim {
                pts[(row, j)] = centers[(c, j)] + spread[c] * rng.normal() as f32;
            }
            row += 1;
        }
    }
    pts
}

/// Cluster labels corresponding to [`gaussian_clusters`] row order.
pub fn cluster_labels(sizes: &[usize]) -> Vec<usize> {
    let mut labels = Vec::with_capacity(sizes.iter().sum());
    for (c, &s) in sizes.iter().enumerate() {
        labels.extend(std::iter::repeat(c).take(s));
    }
    labels
}

/// Validate symmetry + zero diagonal (debug helper used by the CLI).
pub fn validate(d: &Mat) -> Result<(), String> {
    if d.rows() != d.cols() {
        return Err(format!("not square: {}x{}", d.rows(), d.cols()));
    }
    let n = d.rows();
    for x in 0..n {
        if d[(x, x)] != 0.0 {
            return Err(format!("nonzero diagonal at {x}"));
        }
        for y in (x + 1)..n {
            if d[(x, y)] != d[(y, x)] {
                return Err(format!("asymmetric at ({x},{y})"));
            }
            if !(d[(x, y)] > 0.0) {
                return Err(format!("non-positive distance at ({x},{y})"));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tie_free_has_distinct_values() {
        let d = random_tie_free(24, 1);
        validate(&d).unwrap();
        let mut vals = Vec::new();
        for x in 0..24 {
            for y in (x + 1)..24 {
                vals.push(d[(x, y)].to_bits());
            }
        }
        let len = vals.len();
        vals.sort_unstable();
        vals.dedup();
        assert_eq!(vals.len(), len, "found tied distances");
    }

    #[test]
    fn tied_has_ties() {
        let d = random_tied(16, 2, 4);
        validate(&d).unwrap();
        let mut vals = Vec::new();
        for x in 0..16 {
            for y in (x + 1)..16 {
                vals.push(d[(x, y)].to_bits());
            }
        }
        let len = vals.len();
        vals.sort_unstable();
        vals.dedup();
        assert!(vals.len() < len);
    }

    #[test]
    fn duplicated_has_zero_distances_and_ties() {
        let d = random_duplicated(20, 3, 3);
        let n = d.rows();
        let mut zeros = 0;
        for x in 0..n {
            assert_eq!(d[(x, x)], 0.0);
            for y in (x + 1)..n {
                assert_eq!(d[(x, y)], d[(y, x)]);
                if d[(x, y)] == 0.0 {
                    zeros += 1;
                }
            }
        }
        assert!(zeros > 0, "with 20 points over 3 locations duplicates are certain");
    }

    #[test]
    fn euclidean_triangle_inequality() {
        let pts = gaussian_clusters(8, &[10, 10], &[0.5, 0.5], 5.0, 3);
        let d = euclidean(&pts);
        validate(&d).unwrap();
        let n = d.rows();
        for x in 0..n {
            for y in 0..n {
                for z in 0..n {
                    if x != y && y != z && x != z {
                        assert!(d[(x, z)] <= d[(x, y)] + d[(y, z)] + 1e-4);
                    }
                }
            }
        }
    }

    #[test]
    fn clusters_are_separated() {
        let pts = gaussian_clusters(16, &[20, 20], &[0.1, 0.1], 10.0, 7);
        let d = euclidean(&pts);
        // mean within-cluster distance << mean cross-cluster distance
        let (mut win, mut wn, mut cross, mut cn) = (0.0f64, 0, 0.0f64, 0);
        for x in 0..40 {
            for y in (x + 1)..40 {
                if (x < 20) == (y < 20) {
                    win += d[(x, y)] as f64;
                    wn += 1;
                } else {
                    cross += d[(x, y)] as f64;
                    cn += 1;
                }
            }
        }
        assert!(win / wn as f64 * 5.0 < cross / cn as f64);
    }

    #[test]
    fn generators_are_deterministic() {
        assert_eq!(
            random_uniform(12, 5).as_slice(),
            random_uniform(12, 5).as_slice()
        );
        assert_eq!(
            random_tie_free(12, 5).as_slice(),
            random_tie_free(12, 5).as_slice()
        );
    }
}
