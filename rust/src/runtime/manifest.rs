//! Artifact manifest parsing.
//!
//! `artifacts/manifest.json` is produced by `python -m compile.aot`.  Only
//! the subset of JSON that file uses is parsed (flat objects, arrays,
//! strings, numbers) — there is no serde in the offline cache, so a small
//! recursive-descent parser lives here with its own tests.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One AOT-compiled executable.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecutableSpec {
    /// Artifact name (manifest key).
    pub name: String,
    /// Path of the HLO text file, relative to the manifest.
    pub path: String,
    /// Matrix dimension the artifact was compiled for.
    pub n: usize,
    /// Pallas block size baked into the kernel.
    pub block: usize,
    /// "strict" or "split".
    pub tie_mode: String,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifacts directory the manifest was loaded from.
    pub dir: PathBuf,
    /// All AOT-compiled executables listed in the manifest.
    pub executables: Vec<ExecutableSpec>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest JSON text rooted at `dir`.
    pub fn parse(dir: &Path, text: &str) -> anyhow::Result<Manifest> {
        let value = JsonParser::new(text).parse()?;
        let execs = value
            .get("executables")
            .and_then(JsonValue::as_array)
            .ok_or_else(|| anyhow::anyhow!("manifest missing executables"))?;
        let mut executables = Vec::new();
        for e in execs {
            let gets = |k: &str| -> anyhow::Result<String> {
                e.get(k)
                    .and_then(JsonValue::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("missing string field {k}"))
            };
            let getn = |k: &str| -> anyhow::Result<usize> {
                e.get(k)
                    .and_then(JsonValue::as_f64)
                    .map(|v| v as usize)
                    .ok_or_else(|| anyhow::anyhow!("missing numeric field {k}"))
            };
            executables.push(ExecutableSpec {
                name: gets("name")?,
                path: gets("path")?,
                n: getn("n")?,
                block: getn("block")?,
                tie_mode: gets("tie_mode")?,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), executables })
    }

    /// Smallest artifact (by n) that fits a problem of `n` points with the
    /// given tie mode.
    pub fn best_fit(&self, n: usize, tie_mode: &str) -> Option<&ExecutableSpec> {
        self.executables
            .iter()
            .filter(|e| e.n >= n && e.tie_mode == tie_mode)
            .min_by_key(|e| e.n)
    }

    /// Absolute path of an executable's HLO text file.
    pub fn hlo_path(&self, spec: &ExecutableSpec) -> PathBuf {
        self.dir.join(&spec.path)
    }
}

// ---------------------------------------------------------------------
// Minimal JSON parser (objects, arrays, strings, numbers, bools, null).
// ---------------------------------------------------------------------

/// Parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<JsonValue>),
    /// An object.
    Obj(HashMap<String, JsonValue>),
}

impl JsonValue {
    /// Object field lookup (`None` for non-objects).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// The array elements, if this is an array.
    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(v) => Some(*v),
            _ => None,
        }
    }
}

/// Recursive-descent parser over the manifest subset of JSON.
pub struct JsonParser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> JsonParser<'a> {
    /// Parser over `text`.
    pub fn new(text: &'a str) -> Self {
        JsonParser { bytes: text.as_bytes(), pos: 0 }
    }

    /// Parse the whole input as one JSON value (no trailing garbage).
    pub fn parse(mut self) -> anyhow::Result<JsonValue> {
        let v = self.value()?;
        self.skip_ws();
        anyhow::ensure!(self.pos == self.bytes.len(), "trailing garbage at {}", self.pos);
        Ok(v)
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> anyhow::Result<u8> {
        self.skip_ws();
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        let got = self.peek()?;
        anyhow::ensure!(got == c, "expected '{}' got '{}' at {}", c as char, got as char, self.pos);
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<JsonValue> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(JsonValue::Str(self.string()?)),
            b't' => self.literal("true", JsonValue::Bool(true)),
            b'f' => self.literal("false", JsonValue::Bool(false)),
            b'n' => self.literal("null", JsonValue::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, lit: &str, v: JsonValue) -> anyhow::Result<JsonValue> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(lit.as_bytes()),
            "bad literal at {}",
            self.pos
        );
        self.pos += lit.len();
        Ok(v)
    }

    fn object(&mut self) -> anyhow::Result<JsonValue> {
        self.expect(b'{')?;
        let mut map = HashMap::new();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(JsonValue::Obj(map));
        }
        loop {
            let key = self.string()?;
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(map));
                }
                c => anyhow::bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<JsonValue> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(JsonValue::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(arr));
                }
                c => anyhow::bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        while self.pos < self.bytes.len() {
            let c = self.bytes[self.pos];
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self
                        .bytes
                        .get(self.pos)
                        .copied()
                        .ok_or_else(|| anyhow::anyhow!("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'u' => {
                            // \uXXXX (BMP only — enough for our manifests)
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => anyhow::bail!("unsupported escape \\{}", esc as char),
                    }
                }
                _ => out.push(c as char),
            }
        }
        anyhow::bail!("unterminated string")
    }

    fn number(&mut self) -> anyhow::Result<JsonValue> {
        self.skip_ws();
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(JsonValue::Num(s.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
            "format": "hlo-text", "version": 1,
            "executables": [
                {"name": "pald_strict_n128", "path": "pald_strict_n128.hlo.txt",
                 "n": 128, "block": 32, "tie_mode": "strict",
                 "inputs": [{"name": "d", "shape": [128, 128], "dtype": "f32"}],
                 "outputs": [], "sha256": "ab"}
            ]
        }"#;
        let m = Manifest::parse(Path::new("/tmp"), text).unwrap();
        assert_eq!(m.executables.len(), 1);
        let e = &m.executables[0];
        assert_eq!(e.n, 128);
        assert_eq!(e.block, 32);
        assert_eq!(e.tie_mode, "strict");
    }

    #[test]
    fn best_fit_picks_smallest_sufficient() {
        let mk = |n: usize, mode: &str| ExecutableSpec {
            name: format!("pald_{mode}_n{n}"),
            path: String::new(),
            n,
            block: 32,
            tie_mode: mode.into(),
        };
        let m = Manifest {
            dir: PathBuf::new(),
            executables: vec![mk(128, "strict"), mk(512, "strict"), mk(256, "strict"), mk(128, "split")],
        };
        assert_eq!(m.best_fit(100, "strict").unwrap().n, 128);
        assert_eq!(m.best_fit(129, "strict").unwrap().n, 256);
        assert_eq!(m.best_fit(500, "strict").unwrap().n, 512);
        assert!(m.best_fit(513, "strict").is_none());
        assert_eq!(m.best_fit(10, "split").unwrap().n, 128);
    }

    #[test]
    fn json_parser_handles_nesting_and_escapes() {
        let v = JsonParser::new(r#"{"a": [1, 2.5, "x\"y"], "b": {"c": true, "d": null}}"#)
            .parse()
            .unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("a").unwrap().as_array().unwrap()[2].as_str(), Some("x\"y"));
        assert_eq!(v.get("b").unwrap().get("c"), Some(&JsonValue::Bool(true)));
    }

    #[test]
    fn json_parser_rejects_garbage() {
        assert!(JsonParser::new("{").parse().is_err());
        assert!(JsonParser::new("[1,]").parse().is_err());
        assert!(JsonParser::new("{} extra").parse().is_err());
    }

    #[test]
    fn parses_real_artifacts_manifest_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.executables.is_empty());
            assert!(m.best_fit(100, "strict").is_some());
        }
    }
}
