//! XLA/PJRT runtime: loads the AOT-compiled JAX+Pallas artifacts (HLO
//! text, see `python/compile/aot.py`) and executes them on the PJRT CPU
//! client.  Python never runs here — the artifacts are self-contained.

mod client;
mod manifest;

pub use client::{PaldExecutable, XlaRuntime};
pub use manifest::{ExecutableSpec, Manifest};
