//! PJRT client wrapper: HLO text -> compiled executable -> execution with
//! `Mat` inputs/outputs.  Pattern follows /opt/xla-example/load_hlo.

use std::collections::HashMap;
use std::path::Path;

use crate::core::Mat;
use crate::runtime::manifest::{ExecutableSpec, Manifest};

/// A compiled PaLD executable (one artifact variant).
pub struct PaldExecutable {
    /// The manifest entry this executable was compiled from.
    pub spec: ExecutableSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl PaldExecutable {
    /// Execute on a padded `n_art x n_art` distance matrix.
    ///
    /// `d_pad` must already be padded to the artifact size; `n_valid` is
    /// the true point count.  Returns the full padded cohesion matrix.
    pub fn run_padded(&self, d_pad: &Mat, n_valid: usize) -> anyhow::Result<Mat> {
        let n_art = self.spec.n;
        anyhow::ensure!(
            d_pad.rows() == n_art && d_pad.cols() == n_art,
            "expected padded {n_art}x{n_art}, got {}x{}",
            d_pad.rows(),
            d_pad.cols()
        );
        let d_lit = xla::Literal::vec1(d_pad.as_slice()).reshape(&[n_art as i64, n_art as i64])?;
        let mut valid = vec![0.0f32; n_art];
        valid[..n_valid].fill(1.0);
        let valid_lit = xla::Literal::vec1(&valid).reshape(&[n_art as i64])?;
        let nvalid_lit = xla::Literal::scalar(n_valid as f32);

        let result = self.exe.execute::<xla::Literal>(&[d_lit, valid_lit, nvalid_lit])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        anyhow::ensure!(values.len() == n_art * n_art, "unexpected output size");
        Ok(Mat::from_vec(n_art, n_art, values))
    }

    /// Pad an arbitrary `n <= n_art` problem, execute, slice the result.
    pub fn run(&self, d: &Mat, _tie_strict: bool) -> anyhow::Result<Mat> {
        let n = d.rows();
        let n_art = self.spec.n;
        anyhow::ensure!(n <= n_art, "problem n={n} exceeds artifact n={n_art}");
        // Padding contract (see python/compile/model.py): pad value is
        // irrelevant because the valid mask forces padded distances to
        // LARGE inside the graph; zeros keep literals compact.
        let d_pad = if n == n_art { d.clone() } else { d.pad_to(n_art, n_art, 0.0) };
        let c_pad = self.run_padded(&d_pad, n)?;
        Ok(c_pad.slice_to(n, n))
    }
}

/// PJRT CPU runtime holding the client and a compile cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, PaldExecutable>,
}

impl XlaRuntime {
    /// Create a CPU runtime from an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> anyhow::Result<XlaRuntime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(XlaRuntime { client, manifest, cache: HashMap::new() })
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) the best-fitting executable for `n`.
    pub fn executable_for(
        &mut self,
        n: usize,
        tie_mode: &str,
    ) -> anyhow::Result<&PaldExecutable> {
        let spec = self
            .manifest
            .best_fit(n, tie_mode)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact fits n={n} tie_mode={tie_mode}; rebuild with `make artifacts`"
                )
            })?
            .clone();
        if !self.cache.contains_key(&spec.name) {
            let path = self.manifest.hlo_path(&spec);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(spec.name.clone(), PaldExecutable { spec: spec.clone(), exe });
        }
        Ok(&self.cache[&spec.name])
    }
}
