//! Benchmark harness (criterion is unavailable offline): warmup, repeated
//! trials, robust statistics, and Markdown/CSV table emitters shaped like
//! the paper's tables.

use std::time::Instant;

/// Statistics over trial times (seconds).
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    /// Mean trial time (seconds).
    pub mean: f64,
    /// Fastest trial (seconds).
    pub min: f64,
    /// Slowest trial (seconds).
    pub max: f64,
    /// Population standard deviation (seconds).
    pub stddev: f64,
    /// Number of trials measured.
    pub trials: usize,
}

impl Stats {
    /// Summarize a slice of trial times (seconds).
    pub fn from_times(times: &[f64]) -> Stats {
        let n = times.len().max(1) as f64;
        let mean = times.iter().sum::<f64>() / n;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
        Stats {
            mean,
            min: times.iter().cloned().fold(f64::INFINITY, f64::min),
            max: times.iter().cloned().fold(0.0, f64::max),
            stddev: var.sqrt(),
            trials: times.len(),
        }
    }
}

/// Benchmark options.  The paper uses 5 trials and reports means; we default
/// to the same, with a wall-clock budget guard for the big sweeps.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    /// Untimed warmup iterations before measuring.
    pub warmup: usize,
    /// Timed trials.
    pub trials: usize,
    /// Stop early once total measured time exceeds this many seconds.
    pub budget_s: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup: 1, trials: 5, budget_s: 60.0 }
    }
}

impl BenchOpts {
    /// Honor `PALDX_TRIALS` / `PALDX_BUDGET_S` env overrides.
    pub fn from_env() -> Self {
        let mut o = Self::default();
        if let Ok(v) = std::env::var("PALDX_TRIALS") {
            if let Ok(t) = v.parse() {
                o.trials = t;
            }
        }
        if let Ok(v) = std::env::var("PALDX_BUDGET_S") {
            if let Ok(b) = v.parse() {
                o.budget_s = b;
            }
        }
        o
    }
}

/// Time `f` under the options; returns per-trial stats.
pub fn bench<F: FnMut()>(opts: &BenchOpts, mut f: F) -> Stats {
    for _ in 0..opts.warmup {
        f();
    }
    let mut times = Vec::with_capacity(opts.trials);
    let mut spent = 0.0;
    for _ in 0..opts.trials {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        times.push(dt);
        spent += dt;
        if spent > opts.budget_s && !times.is_empty() {
            break;
        }
    }
    Stats::from_times(&times)
}

/// Is the full paper-scale suite requested? (`PALDX_FULL=1`)
pub fn full_scale() -> bool {
    std::env::var("PALDX_FULL").map(|v| v == "1").unwrap_or(false)
}

/// A machine-readable measurement attached to a table: one benchmarked
/// algorithm/configuration with its trial statistics.
#[derive(Clone, Debug)]
pub struct StatEntry {
    /// Algorithm or configuration label (e.g. `opt-pairwise/n=512`).
    pub label: String,
    /// The measured trial statistics.
    pub stats: Stats,
}

/// A printable results table, optionally carrying the raw [`Stats`]
/// behind its formatted cells so the JSON report can be emitted alongside
/// the Markdown.
pub struct Table {
    /// Table caption (becomes the Markdown `###` heading).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Formatted cell rows (each the same length as `headers`).
    pub rows: Vec<Vec<String>>,
    /// Raw statistics backing the formatted rows (may be empty for
    /// simulation-only tables).
    pub stats: Vec<StatEntry>,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            stats: Vec::new(),
        }
    }

    /// Append one formatted row (must match the header count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Record the raw statistics behind a formatted row.
    pub fn stat(&mut self, label: impl Into<String>, stats: Stats) {
        self.stats.push(StatEntry { label: label.into(), stats });
    }

    /// Markdown rendering (the format EXPERIMENTS.md embeds directly).
    pub fn markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let cols: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:width$}", c, width = widths[i]))
                .collect();
            format!("| {} |", cols.join(" | "))
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (headers + rows).
    pub fn csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Print to stdout (benches call this at the end).
    pub fn print(&self) {
        println!("{}", self.markdown());
    }
}

/// Minimal JSON string escaping (labels/titles are plain ASCII-ish).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON document for one experiment's measured tables: per-algorithm
/// mean/min/max/stddev (seconds) and trial counts, so the perf trajectory
/// can be tracked across PRs (`BENCH_<exp>.json`).
pub fn json_report(exp: &str, tables: &[&Table]) -> String {
    let mut out = String::new();
    out.push_str(&format!("{{\n  \"experiment\": \"{}\",\n  \"tables\": [", json_escape(exp)));
    let mut first_t = true;
    for t in tables {
        if !first_t {
            out.push(',');
        }
        first_t = false;
        out.push_str(&format!("\n    {{\n      \"title\": \"{}\",\n      \"entries\": [", json_escape(&t.title)));
        let mut first_e = true;
        for e in &t.stats {
            if !first_e {
                out.push(',');
            }
            first_e = false;
            out.push_str(&format!(
                "\n        {{\"label\": \"{}\", \"mean_s\": {:.9e}, \"min_s\": {:.9e}, \"max_s\": {:.9e}, \"stddev_s\": {:.9e}, \"trials\": {}}}",
                json_escape(&e.label),
                e.stats.mean,
                e.stats.min,
                e.stats.max,
                e.stats.stddev,
                e.stats.trials
            ));
        }
        out.push_str("\n      ]\n    }");
    }
    out.push_str("\n  ]\n}\n");
    out
}

/// Where `BENCH_<exp>.json` reports land when no `--bench-dir` is
/// given: the enclosing repository root (the first ancestor holding a
/// `.git` entry), found by walking up from the working directory.
/// Bench binaries run with `rust/` as their working directory, which
/// used to scatter CWD-relative reports there instead of the repo root
/// the perf-trajectory tooling scrapes.  A `PALD_BENCH_DIR`
/// environment variable overrides the walk; with no repository marker
/// in sight the current directory is kept.
pub fn default_bench_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("PALD_BENCH_DIR") {
        if !dir.is_empty() {
            return std::path::PathBuf::from(dir);
        }
    }
    let start = std::env::current_dir().unwrap_or_else(|_| std::path::PathBuf::from("."));
    let mut cur = start.as_path();
    loop {
        if cur.join(".git").exists() {
            return cur.to_path_buf();
        }
        match cur.parent() {
            Some(parent) => cur = parent,
            None => return std::path::PathBuf::from("."),
        }
    }
}

/// Write an explicit skip record for an experiment that cannot run on
/// this host (e.g. `xla` without compiled PJRT artifacts):
/// `BENCH_<exp>.json` with `"skipped": true` and the reason, so the
/// perf-trajectory scrape sees a deliberate skip instead of a missing
/// or failing report.
pub fn write_skip_report(
    dir: &std::path::Path,
    exp: &str,
    reason: &str,
) -> std::io::Result<std::path::PathBuf> {
    let path = dir.join(format!("BENCH_{exp}.json"));
    let body = format!(
        "{{\n  \"experiment\": \"{}\",\n  \"skipped\": true,\n  \"reason\": \"{}\",\n  \"tables\": []\n}}\n",
        json_escape(exp),
        json_escape(reason)
    );
    std::fs::write(&path, body)?;
    Ok(path)
}

/// Write `BENCH_<exp>.json` for an experiment's tables if any of them
/// carry raw stats; returns the path written.
pub fn write_json_report(
    dir: &std::path::Path,
    exp: &str,
    tables: &[&Table],
) -> std::io::Result<Option<std::path::PathBuf>> {
    if tables.iter().all(|t| t.stats.is_empty()) {
        return Ok(None);
    }
    let path = dir.join(format!("BENCH_{exp}.json"));
    std::fs::write(&path, json_report(exp, tables))?;
    Ok(Some(path))
}

/// Human formatting helpers used across benches.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Format a speedup ratio (`1.50x`).
pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_times(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.trials, 3);
    }

    #[test]
    fn bench_runs_and_counts() {
        let mut count = 0;
        let opts = BenchOpts { warmup: 2, trials: 3, budget_s: 100.0 };
        let s = bench(&opts, || count += 1);
        assert_eq!(count, 5); // 2 warmup + 3 trials
        assert_eq!(s.trials, 3);
    }

    #[test]
    fn budget_stops_early() {
        let opts = BenchOpts { warmup: 0, trials: 100, budget_s: 0.02 };
        let s = bench(&opts, || std::thread::sleep(std::time::Duration::from_millis(15)));
        assert!(s.trials < 100);
    }

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("Table 1", &["n", "time"]);
        t.row(vec!["128".into(), "0.001".into()]);
        let md = t.markdown();
        assert!(md.contains("### Table 1"));
        assert!(md.contains("| n   | time  |"));
        assert!(md.lines().count() >= 5);
    }

    #[test]
    fn json_report_shape() {
        let mut t = Table::new("Figure 3 — ladder", &["variant", "time"]);
        t.row(vec!["naive".into(), "1.0".into()]);
        t.stat("naive-pairwise", Stats::from_times(&[1.0, 2.0]));
        let js = json_report("fig3", &[&t]);
        assert!(js.contains("\"experiment\": \"fig3\""));
        assert!(js.contains("\"label\": \"naive-pairwise\""));
        assert!(js.contains("\"trials\": 2"));
        // escaping
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn json_report_skipped_without_stats() {
        let t = Table::new("sim only", &["a"]);
        let dir = std::env::temp_dir();
        let wrote = write_json_report(&dir, "simexp", &[&t]).unwrap();
        assert!(wrote.is_none());
    }

    #[test]
    fn json_report_written_with_stats() {
        let mut t = Table::new("measured", &["a"]);
        t.stat("x", Stats::from_times(&[0.5]));
        let dir = std::env::temp_dir();
        let path = write_json_report(&dir, "paldx_test_exp", &[&t]).unwrap().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"experiment\": \"paldx_test_exp\""));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn skip_report_records_the_reason() {
        let dir = std::env::temp_dir();
        let skip = write_skip_report(&dir, "paldx_test_skip", "no artifacts on this host").unwrap();
        assert_eq!(
            skip.file_name().unwrap().to_str().unwrap(),
            "BENCH_paldx_test_skip.json"
        );
        let body = std::fs::read_to_string(&skip).unwrap();
        assert!(body.contains("\"skipped\": true"), "{body}");
        assert!(body.contains("no artifacts on this host"), "{body}");
        std::fs::remove_file(&skip).unwrap();
    }

    #[test]
    fn default_bench_dir_resolves_to_the_repo_root() {
        // The test binary runs inside the repository, so the walk must
        // land on the directory that holds `.git` (never fall through
        // to a CWD-relative dot on a checked-out tree).
        let dir = default_bench_dir();
        assert!(
            dir.join(".git").exists() || dir == std::path::Path::new("."),
            "unexpected bench dir {}",
            dir.display()
        );
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_secs(0.5e-4), "50.0µs");
        assert_eq!(fmt_secs(0.5), "500.00ms");
        assert_eq!(fmt_secs(2.0), "2.000s");
        assert_eq!(fmt_speedup(1.5), "1.50x");
    }
}
