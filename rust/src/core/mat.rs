//! Row-major dense `f32` matrix.
//!
//! Deliberately minimal: the PaLD kernels index raw rows for speed, and the
//! rest of the crate only needs construction, transpose, and simple
//! reductions.  Row-major layout is the crate-wide convention; the paper's
//! "stride-1 column updates of C" correspond to our stride-1 *row* updates
//! (their matrices are column-major).

use std::fmt;
use std::ops::{Index, IndexMut};

/// Row-major dense matrix of `f32`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with `v`.
    pub fn filled(rows: usize, cols: usize, v: f32) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    /// Build from a row-major vector (length must equal `rows * cols`).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Build an `n x n` matrix from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Number of rows.
    #[inline(always)]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline(always)]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// `true` when the matrix holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Contiguous row slice.
    #[inline(always)]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable contiguous row slice.
    #[inline(always)]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Two disjoint mutable rows (`r1 != r2`), for the pairwise kernels that
    /// update the cohesion rows of both endpoints of a pair in one pass.
    pub fn two_rows_mut(&mut self, r1: usize, r2: usize) -> (&mut [f32], &mut [f32]) {
        assert_ne!(r1, r2);
        let c = self.cols;
        if r1 < r2 {
            let (a, b) = self.data.split_at_mut(r2 * c);
            (&mut a[r1 * c..r1 * c + c], &mut b[..c])
        } else {
            let (a, b) = self.data.split_at_mut(r1 * c);
            let (rb, ra) = (&mut a[r2 * c..r2 * c + c], &mut b[..c]);
            (ra, rb)
        }
    }

    /// Flat row-major data.
    #[inline(always)]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat row-major data.
    #[inline(always)]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Raw mutable pointer (used by the task-graph executor, which guards
    /// disjoint tile writes with tile locks).
    pub fn as_mut_ptr(&mut self) -> *mut f32 {
        self.data.as_mut_ptr()
    }

    /// Multiply every element by `s`.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Sum of all elements (f64 accumulator).
    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&v| v as f64).sum()
    }

    /// Sum of the main diagonal (square matrices).
    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self[(i, i)] as f64).sum()
    }

    /// Out-of-place transpose.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            let r = self.row(i);
            for j in 0..self.cols {
                t[(j, i)] = r[j];
            }
        }
        t
    }

    /// Maximum absolute elementwise difference against `other`.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// `true` if elementwise within `atol + rtol * |other|`.
    pub fn allclose(&self, other: &Mat, rtol: f32, atol: f32) -> bool {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    /// Copy `self` into the top-left corner of a larger zero matrix,
    /// used by the coordinator's pad-to-artifact-size path.
    pub fn pad_to(&self, rows: usize, cols: usize, fill: f32) -> Mat {
        assert!(rows >= self.rows && cols >= self.cols);
        let mut out = Mat::filled(rows, cols, fill);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    /// Top-left `rows x cols` sub-matrix copy (inverse of [`Mat::pad_to`]).
    pub fn slice_to(&self, rows: usize, cols: usize) -> Mat {
        assert!(rows <= self.rows && cols <= self.cols);
        let mut out = Mat::zeros(rows, cols);
        for i in 0..rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[..cols]);
        }
        out
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline(always)]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline(always)]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(6);
        for i in 0..show {
            let r = self.row(i);
            let cols = r.iter().take(8).map(|v| format!("{v:10.5}")).collect::<Vec<_>>();
            writeln!(f, "  [{}{}]", cols.join(", "), if self.cols > 8 { ", ..." } else { "" })?;
        }
        if self.rows > show {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_index() {
        let mut m = Mat::zeros(3, 4);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 4);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1)[2], 5.0);
    }

    #[test]
    fn from_fn_and_transpose() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 10 + j) as f32);
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        for i in 0..2 {
            for j in 0..3 {
                assert_eq!(m[(i, j)], t[(j, i)]);
            }
        }
    }

    #[test]
    fn two_rows_mut_disjoint() {
        let mut m = Mat::from_fn(4, 3, |i, _| i as f32);
        {
            let (a, b) = m.two_rows_mut(3, 1);
            a[0] = 30.0;
            b[0] = 10.0;
        }
        assert_eq!(m[(3, 0)], 30.0);
        assert_eq!(m[(1, 0)], 10.0);
        let (a, b) = m.two_rows_mut(0, 2);
        a[1] = 1.0;
        b[1] = 2.0;
        drop((a, b));
        assert_eq!(m[(0, 1)], 1.0);
        assert_eq!(m[(2, 1)], 2.0);
    }

    #[test]
    #[should_panic]
    fn two_rows_mut_same_row_panics() {
        let mut m = Mat::zeros(2, 2);
        let _ = m.two_rows_mut(1, 1);
    }

    #[test]
    fn pad_and_slice_roundtrip() {
        let m = Mat::from_fn(3, 3, |i, j| (i + j) as f32);
        let p = m.pad_to(5, 5, 9.0);
        assert_eq!(p[(4, 4)], 9.0);
        assert_eq!(p[(2, 1)], 3.0);
        let s = p.slice_to(3, 3);
        assert_eq!(s, m);
    }

    #[test]
    fn sums_and_scale() {
        let mut m = Mat::filled(2, 2, 2.0);
        assert_eq!(m.sum(), 8.0);
        assert_eq!(m.trace(), 4.0);
        m.scale(0.5);
        assert_eq!(m.sum(), 4.0);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Mat::filled(2, 2, 1.0);
        let mut b = a.clone();
        b[(0, 0)] = 1.0 + 1e-6;
        assert!(a.allclose(&b, 1e-5, 0.0));
        assert!(!a.allclose(&b, 1e-8, 1e-9));
    }
}
