//! Core dense-matrix types shared by every layer of the stack.

mod mat;

pub use mat::Mat;

/// Largest representable "infinite" distance used by the padding contract
/// (must match `python/compile/model.py::LARGE`).
pub const LARGE_DISTANCE: f32 = 1e30;
