"""L2 compute graph: full PaLD cohesion with padding semantics.

``pald_cohesion(d, valid, n_valid)`` composes the two Pallas passes
(focus sizes -> reciprocal weights -> cohesion) exactly like the paper's
two-pass blocked algorithms, and adds the padding contract the Rust
coordinator relies on:

* the artifact is compiled for a fixed n (128/256/512); the coordinator
  right-pads a smaller problem with dummy points;
* ``valid`` is a {0,1} float mask over rows; for any pair involving a
  padded point the effective distance is LARGE, so padded points never
  enter any real pair's local focus, and the pair weight is forced to 0 so
  padded pairs contribute no cohesion;
* ``n_valid`` (scalar, float) is the true number of points, used for the
  1/(n-1) normalization.

Rows/columns of the result that correspond to padded points are garbage by
contract and are sliced away by the caller.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels import pald_kernels

__all__ = ["pald_cohesion"]

# Any finite pairwise distance must be < LARGE for the padding contract.
LARGE = 1e30


@partial(jax.jit, static_argnames=("block", "tie_split"))
def pald_cohesion(d, valid, n_valid, *, block=64, tie_split=False):
    """Cohesion matrix C (n, n) from distance matrix d (n, n).

    Returns C normalized by 1/(n_valid - 1).
    """
    n = d.shape[0]
    vpair = valid[:, None] * valid[None, :]  # (n, n) {0,1}
    d_eff = jnp.where(vpair > 0.5, d, LARGE)

    u = pald_kernels.focus_sizes(d_eff, block=block, tie_split=tie_split)

    off_diag = 1.0 - jnp.eye(n, dtype=jnp.float32)
    w = vpair * off_diag / jnp.maximum(u, 1.0)

    c = pald_kernels.cohesion(d_eff, w, block=block, tie_split=tie_split)
    return c / jnp.maximum(n_valid - 1.0, 1.0)
