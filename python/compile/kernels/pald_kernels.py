"""Pallas kernels for blocked, branch-free PaLD (paper Sections 3 and 5).

The paper's two optimizations that matter most — cache blocking and branch
avoidance via masked FMAs — map directly onto Pallas:

* cache blocking   -> ``BlockSpec`` tiles: each grid step holds one D row
  panel (bx, n), one transposed panel (bz, n), and one (bx, bz) output tile
  in VMEM; the HBM<->VMEM schedule is exactly Figure 1's DRAM<->cache
  schedule.
* branch avoidance -> comparisons produce {0, 1} float masks and the
  cohesion update is ``acc += focus * support * w`` — the paper's explicit
  masked-FMA form.  (TPU vector cores have no branch unit at all, so this is
  the only possible formulation; the paper's CPU insight is mandatory here.)

Two kernels mirror the paper's two passes over the data:

* ``focus_sizes``  — grid over (X, Y) block pairs, reduces over z chunks to
  produce the local-focus size tile U[X, Y].
* ``cohesion``     — grid over (X, Z) block pairs, reduces over y chunks to
  produce the cohesion tile C[X, Z].  The z-minor tiling gives every grid
  step exclusive ownership of its C tile: no scatter, no write conflicts by
  construction (the paper's "stride-1 column updates" in Figure 6).

Both kernels are compiled with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and correctness is what the AOT path
needs.  Real-TPU VMEM sizing is analyzed in DESIGN.md §Hardware-Adaptation.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["focus_sizes", "cohesion"]


def _focus_kernel(dx_ref, dy_ref, u_ref, *, bx, by, bz, n, tie_split):
    """U[X, Y] tile: count z with d_xz (<|<=) d_xy or d_yz (<|<=) d_xy."""
    j = pl.program_id(1)
    dx = dx_ref[...]  # (bx, n): distances from X-block points to all z
    dy = dy_ref[...]  # (by, n): distances from Y-block points to all z
    dxy = lax.dynamic_slice(dx, (0, j * by), (bx, by))  # (bx, by)

    def body(k, acc):
        dxz = lax.dynamic_slice(dx, (0, k * bz), (bx, bz))  # (bx, bz)
        dyz = lax.dynamic_slice(dy, (0, k * bz), (by, bz))  # (by, bz)
        if tie_split:
            m = (dxz[:, None, :] <= dxy[:, :, None]) | (
                dyz[None, :, :] <= dxy[:, :, None]
            )
        else:
            m = (dxz[:, None, :] < dxy[:, :, None]) | (
                dyz[None, :, :] < dxy[:, :, None]
            )
        return acc + jnp.sum(m.astype(jnp.float32), axis=2)

    u_ref[...] = lax.fori_loop(0, n // bz, body, jnp.zeros((bx, by), jnp.float32))


def _cohesion_kernel(dx_ref, dz_ref, w_ref, c_ref, *, bx, by, bz, n, tie_split):
    """C[X, Z] tile (unnormalized): sum over y of focus * support * w[x, y]."""
    j = pl.program_id(1)
    dx = dx_ref[...]  # (bx, n): row panel for X-block
    dz = dz_ref[...]  # (bz, n): row panel for Z-block (D symmetric: row z = col z)
    w = w_ref[...]  # (bx, n): pair weights w[x, y] = valid/u_xy, 0 on diag
    dxz = lax.dynamic_slice(dx, (0, j * bz), (bx, bz))  # (bx, bz)

    def body(k, acc):
        dxy = lax.dynamic_slice(dx, (0, k * by), (bx, by))  # (bx, by)
        dzy = lax.dynamic_slice(dz, (0, k * by), (bz, by))  # (bz, by)
        wxy = lax.dynamic_slice(w, (0, k * by), (bx, by))  # (bx, by)
        dyz = dzy.T  # (by, bz)
        a = dxz[:, None, :]  # (bx, 1, bz)
        b = dxy[:, :, None]  # (bx, by, 1)
        c = dyz[None, :, :]  # (1, by, bz)
        if tie_split:
            focus = ((a <= b) | (c <= b)).astype(jnp.float32)
            support = (a < c).astype(jnp.float32) + 0.5 * (a == c).astype(
                jnp.float32
            )
        else:
            focus = ((a < b) | (c < b)).astype(jnp.float32)
            support = (a < c).astype(jnp.float32)
        return acc + jnp.einsum("xyz,xy->xz", focus * support, wxy)

    c_ref[...] = lax.fori_loop(0, n // by, body, jnp.zeros((bx, bz), jnp.float32))


@partial(jax.jit, static_argnames=("block", "tie_split"))
def focus_sizes(d, *, block=64, tie_split=False):
    """Blocked Pallas computation of the local-focus size matrix U.

    ``d`` must be (n, n) float32 with n divisible by ``block``.
    """
    n = d.shape[0]
    b = min(block, n)
    assert n % b == 0, f"n={n} must be divisible by block={b}"
    kern = partial(_focus_kernel, bx=b, by=b, bz=b, n=n, tie_split=tie_split)
    return pl.pallas_call(
        kern,
        grid=(n // b, n // b),
        in_specs=[
            pl.BlockSpec((b, n), lambda i, j: (i, 0)),
            pl.BlockSpec((b, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((b, b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(d, d)


@partial(jax.jit, static_argnames=("block", "tie_split"))
def cohesion(d, w, *, block=64, tie_split=False):
    """Blocked Pallas computation of the unnormalized cohesion matrix.

    ``w`` is the precomputed pair-weight matrix (1/u_xy off-diagonal for
    valid pairs, else 0) — the paper's "precompute reciprocals of U once"
    optimization lifted out of the inner loop.
    """
    n = d.shape[0]
    b = min(block, n)
    assert n % b == 0, f"n={n} must be divisible by block={b}"
    kern = partial(_cohesion_kernel, bx=b, by=b, bz=b, n=n, tie_split=tie_split)
    return pl.pallas_call(
        kern,
        grid=(n // b, n // b),
        in_specs=[
            pl.BlockSpec((b, n), lambda i, j: (i, 0)),
            pl.BlockSpec((b, n), lambda i, j: (j, 0)),
            pl.BlockSpec((b, n), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(d, d, w)
