"""Pure-jnp PaLD oracle.

This is the correctness reference for both the Pallas kernels (L1) and,
via golden files, the Rust implementations (L3). It evaluates the ordered
formulation of Eq. (3.3) in the paper directly with O(n^3) broadcasting:

    C[x, z] = (1/(n-1)) * sum_{y != x}  focus(x, y, z) * support(x, y, z) / u_xy

Two tie modes (paper Section 5):

* ``strict``  — the optimized C code's semantics: focus membership uses
  strict ``<`` comparisons, the supporter test is ``d_xz < d_yz``.  Only
  well-defined on tie-free distance matrices (ties are measure zero for
  continuous data, which is exactly the paper's argument for eliding them).
* ``split``   — the theoretical formulation of Berenhaut et al. [2]: focus
  membership uses ``<=`` and distance ties split support 0.5/0.5.  Fully
  symmetric; used for exact cross-implementation equality tests.

The diagonal is included: for the pair (x, y), the third point z = x always
lies in the focus and supports x, so ``C[x, x]`` accumulates sum_y 1/u_xy.
"""

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["focus_sizes_ref", "cohesion_ref", "strong_tie_threshold"]


@partial(jax.jit, static_argnames=("tie_split",))
def focus_sizes_ref(d, tie_split=False):
    """Local-focus sizes U[x, y] = |{z : d_xz (<|<=) d_xy or d_yz (<|<=) d_xy}|.

    Returns an (n, n) float32 matrix; the diagonal is meaningless (a point
    has no focus with itself) and is left as computed.
    """
    dxy = d[:, :, None]  # indexed [x, y, 1]
    dxz = d[:, None, :]  # indexed [x, 1, z]
    dyz = d[None, :, :]  # indexed [1, y, z]
    if tie_split:
        in_focus = (dxz <= dxy) | (dyz <= dxy)
    else:
        in_focus = (dxz < dxy) | (dyz < dxy)
    return jnp.sum(in_focus.astype(jnp.float32), axis=2)


@partial(jax.jit, static_argnames=("tie_split",))
def cohesion_ref(d, tie_split=False):
    """Full cohesion matrix C (normalized by 1/(n-1)) from distance matrix d."""
    n = d.shape[0]
    dxy = d[:, :, None]
    dxz = d[:, None, :]
    dyz = d[None, :, :]
    if tie_split:
        in_focus = (dxz <= dxy) | (dyz <= dxy)
        support = (dxz < dyz).astype(jnp.float32) + 0.5 * (dxz == dyz).astype(
            jnp.float32
        )
    else:
        in_focus = (dxz < dxy) | (dyz < dxy)
        support = (dxz < dyz).astype(jnp.float32)

    u = jnp.sum(in_focus.astype(jnp.float32), axis=2)
    # Pair weights: 1/u_xy for y != x, 0 on the diagonal (no self pair).
    off_diag = 1.0 - jnp.eye(n, dtype=jnp.float32)
    w = off_diag * (1.0 / jnp.maximum(u, 1.0))
    g = in_focus.astype(jnp.float32) * support * w[:, :, None]
    return jnp.sum(g, axis=1) / (n - 1)


def strong_tie_threshold(c):
    """Universal strong-tie threshold: half the mean of the diagonal of C."""
    return 0.5 * jnp.mean(jnp.diag(c))
