"""AOT lowering: L2 model -> HLO text artifacts + manifest.

HLO *text* (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids, so text
round-trips cleanly.  See /opt/xla-example/load_hlo.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (n, block, tie_split) variants shipped as artifacts.  The Rust coordinator
# pads any n' <= n problem to the nearest variant.
VARIANTS = [
    (128, 32, False),
    (128, 32, True),
    (256, 64, False),
    (512, 64, False),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(n: int, block: int, tie_split: bool) -> str:
    d = jax.ShapeDtypeStruct((n, n), jnp.float32)
    valid = jax.ShapeDtypeStruct((n,), jnp.float32)
    n_valid = jax.ShapeDtypeStruct((), jnp.float32)

    def fn(d, valid, n_valid):
        return (model.pald_cohesion(d, valid, n_valid, block=block,
                                    tie_split=tie_split),)

    return to_hlo_text(jax.jit(fn).lower(d, valid, n_valid))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    entries = []
    for n, block, tie_split in VARIANTS:
        mode = "split" if tie_split else "strict"
        name = f"pald_{mode}_n{n}"
        path = f"{name}.hlo.txt"
        text = lower_variant(n, block, tie_split)
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "path": path,
                "n": n,
                "block": block,
                "tie_mode": mode,
                "inputs": [
                    {"name": "d", "shape": [n, n], "dtype": "f32"},
                    {"name": "valid", "shape": [n], "dtype": "f32"},
                    {"name": "n_valid", "shape": [], "dtype": "f32"},
                ],
                "outputs": [{"name": "c", "shape": [n, n], "dtype": "f32"}],
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"wrote {path}  ({len(text)} chars)")

    manifest = {"format": "hlo-text", "version": 1, "executables": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(entries)} executables)")


if __name__ == "__main__":
    main()
