"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the core correctness signal for the compiled artifacts: everything
the Rust runtime executes lowers through these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pald_kernels, ref

jax.config.update("jax_platform_name", "cpu")


def rand_dist(n, seed=0, tie_free=True):
    """Random symmetric distance matrix with zero diagonal.

    With tie_free=True all off-diagonal values are distinct (strict-mode
    semantics are only defined on tie-free inputs, mirroring the paper's
    tie-elision argument).
    """
    rng = np.random.default_rng(seed)
    iu = np.triu_indices(n, 1)
    m = iu[0].size
    if tie_free:
        vals = (rng.permutation(m) + 1.0) / m + rng.uniform(0.1, 1.0)
    else:
        vals = rng.integers(1, 6, size=m).astype(np.float64)
    d = np.zeros((n, n), dtype=np.float32)
    d[iu] = vals
    d += d.T
    return jnp.asarray(d)


@pytest.mark.parametrize("n,block", [(8, 4), (16, 4), (32, 8), (64, 16), (128, 32)])
@pytest.mark.parametrize("tie_split", [False, True])
def test_focus_sizes_matches_ref(n, block, tie_split):
    d = rand_dist(n, seed=n, tie_free=not tie_split)
    got = pald_kernels.focus_sizes(d, block=block, tie_split=tie_split)
    want = ref.focus_sizes_ref(d, tie_split=tie_split)
    # Off-diagonal entries must match exactly (integer-valued counts).
    mask = ~np.eye(n, dtype=bool)
    np.testing.assert_array_equal(np.asarray(got)[mask], np.asarray(want)[mask])


@pytest.mark.parametrize("n,block", [(8, 4), (16, 8), (32, 8), (64, 32), (128, 32)])
@pytest.mark.parametrize("tie_split", [False, True])
def test_cohesion_matches_ref(n, block, tie_split):
    d = rand_dist(n, seed=100 + n, tie_free=not tie_split)
    u = ref.focus_sizes_ref(d, tie_split=tie_split)
    w = (1.0 - jnp.eye(n)) / jnp.maximum(u, 1.0)
    got = pald_kernels.cohesion(d, w, block=block, tie_split=tie_split) / (n - 1)
    want = ref.cohesion_ref(d, tie_split=tie_split)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-6)


def test_focus_sizes_min_two():
    """u_xy >= 2: x and y always belong to their own local focus."""
    d = rand_dist(32, seed=7)
    u = np.asarray(pald_kernels.focus_sizes(d, block=8))
    mask = ~np.eye(32, dtype=bool)
    assert (u[mask] >= 2).all()
    assert (u[mask] <= 32).all()


def test_cohesion_total_mass():
    """sum(C) == n/2: each pair distributes exactly one unit of support."""
    n = 64
    d = rand_dist(n, seed=3)
    u = ref.focus_sizes_ref(d)
    w = (1.0 - jnp.eye(n)) / jnp.maximum(u, 1.0)
    c = pald_kernels.cohesion(d, w, block=16) / (n - 1)
    np.testing.assert_allclose(float(jnp.sum(c)), n / 2, rtol=1e-5)


def test_scale_invariance():
    """Cohesion depends only on relative distances (paper Section 2)."""
    n = 32
    d = rand_dist(n, seed=11)
    u1 = ref.focus_sizes_ref(d)
    w1 = (1.0 - jnp.eye(n)) / jnp.maximum(u1, 1.0)
    c1 = pald_kernels.cohesion(d, w1, block=8)
    d2 = d * 37.5
    u2 = ref.focus_sizes_ref(d2)
    w2 = (1.0 - jnp.eye(n)) / jnp.maximum(u2, 1.0)
    c2 = pald_kernels.cohesion(d2, w2, block=8)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    n_blocks=st.integers(2, 6),
    block=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
    tie_split=st.booleans(),
)
def test_kernel_vs_ref_hypothesis(n_blocks, block, seed, tie_split):
    """Shape/blocking sweep: kernel == oracle for arbitrary divisible shapes."""
    n = n_blocks * block
    d = rand_dist(n, seed=seed, tie_free=True)
    u_k = pald_kernels.focus_sizes(d, block=block, tie_split=tie_split)
    u_r = ref.focus_sizes_ref(d, tie_split=tie_split)
    mask = ~np.eye(n, dtype=bool)
    np.testing.assert_array_equal(np.asarray(u_k)[mask], np.asarray(u_r)[mask])
    w = (1.0 - jnp.eye(n)) / jnp.maximum(u_r, 1.0)
    c_k = pald_kernels.cohesion(d, w, block=block, tie_split=tie_split) / (n - 1)
    c_r = ref.cohesion_ref(d, tie_split=tie_split)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), rtol=1e-4,
                               atol=1e-6)


def test_split_mode_handles_ties_symmetrically():
    """With integer (tied) distances, split mode is permutation-consistent."""
    n = 16
    d = rand_dist(n, seed=5, tie_free=False)
    c = np.asarray(ref.cohesion_ref(d, tie_split=True))
    perm = np.random.default_rng(0).permutation(n)
    dp = jnp.asarray(np.asarray(d)[np.ix_(perm, perm)])
    cp = np.asarray(ref.cohesion_ref(dp, tie_split=True))
    np.testing.assert_allclose(cp, c[np.ix_(perm, perm)], rtol=1e-5, atol=1e-7)
