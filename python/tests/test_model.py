"""L2 correctness: model composition + padding contract + AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref
from tests.test_kernels import rand_dist

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("n,block", [(16, 4), (32, 8), (64, 16)])
def test_model_matches_ref(n, block):
    d = rand_dist(n, seed=n)
    valid = jnp.ones((n,), jnp.float32)
    c = model.pald_cohesion(d, valid, jnp.float32(n), block=block)
    want = ref.cohesion_ref(d)
    np.testing.assert_allclose(np.asarray(c), np.asarray(want), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("n_real", [9, 17, 24, 31])
def test_padding_contract(n_real):
    """Padding to the artifact size must not change the valid block of C."""
    n_pad = 32
    d_real = rand_dist(n_real, seed=n_real)
    want = ref.cohesion_ref(d_real)

    d_pad = np.zeros((n_pad, n_pad), dtype=np.float32)
    d_pad[:n_real, :n_real] = np.asarray(d_real)
    valid = np.zeros((n_pad,), dtype=np.float32)
    valid[:n_real] = 1.0
    c = model.pald_cohesion(
        jnp.asarray(d_pad), jnp.asarray(valid), jnp.float32(n_real), block=8
    )
    got = np.asarray(c)[:n_real, :n_real]
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-5, atol=1e-6)


def test_padded_rows_contribute_nothing():
    """Total support mass must be n_real/2 regardless of padding."""
    n_real, n_pad = 12, 16
    d_real = rand_dist(n_real, seed=1)
    d_pad = np.zeros((n_pad, n_pad), dtype=np.float32)
    d_pad[:n_real, :n_real] = np.asarray(d_real)
    valid = np.zeros((n_pad,), dtype=np.float32)
    valid[:n_real] = 1.0
    c = model.pald_cohesion(
        jnp.asarray(d_pad), jnp.asarray(valid), jnp.float32(n_real), block=4
    )
    total = float(jnp.sum(c[:n_real, :n_real]))
    np.testing.assert_allclose(total, n_real / 2, rtol=1e-5)


def test_aot_lowering_produces_hlo_text():
    """The AOT path must produce parseable HLO text for a small variant."""
    from compile import aot

    text = aot.lower_variant(16, 4, False)
    assert "HloModule" in text
    assert "ENTRY" in text
